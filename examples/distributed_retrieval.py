"""Beyond-paper demo: distributed wave attention (shard_map local retrieval +
LSE psum) vs the serial path, on 8 simulated devices.

    PYTHONPATH=src python examples/distributed_retrieval.py
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import RetroConfig
from repro.core.attention import wave_attention_decode
from repro.core.distributed import distributed_wave_attention
from repro.core.wave_index import max_clusters, prefill_build
from repro.core.zones import plan_zones
from repro.data.pipeline import clustered_keys


def main():
    n, hd = 16384, 64
    retro = RetroConfig(avg_cluster=16, cluster_cap=32, prefill_segment=1024,
                        update_segment=256, sink=4, local=64, kmeans_iters=5)
    keys, q, hot = clustered_keys(n, hd, n_hot=8, seed=0)
    vals = np.random.default_rng(1).standard_normal((n, hd)).astype(np.float32)
    k = jnp.asarray(keys)[None, :, None, :]
    v = jnp.asarray(vals)[None, :, None, :]
    state = prefill_build(k, v, retro, max_clusters(n, retro, 256),
                          dtype=jnp.float32)
    qj = jnp.asarray(q)[None, None, :]
    plan = plan_zones(n, retro, 256)

    serial = wave_attention_decode(qj, state, retro, plan).out
    for n_dev in (1, 2, 4, 8):
        mesh = jax.make_mesh((n_dev,), ("model",))
        dist = distributed_wave_attention(qj, state, retro, plan, mesh)
        rel = float(jnp.linalg.norm(dist - serial)
                    / jnp.linalg.norm(serial))
        print(f"shards={n_dev}: local top-{max(1, -(-plan.r // n_dev))} "
              f"per shard, rel diff vs serial global top-{plan.r}: {rel:.5f}")
    print("collective payload per step: one (num, den, max) psum = "
          f"{(hd + 2) * 4} bytes/head vs "
          f"{plan.r * retro.cluster_cap * hd * 2 * 4} bytes of KV blocks "
          "for a cross-shard gather")


if __name__ == "__main__":
    main()
