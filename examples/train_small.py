"""Train a small (~15M param) dense model for a few hundred steps on the
synthetic pipeline, checkpoint, restore, and continue — exercising the full
training substrate.

    PYTHONPATH=src python examples/train_small.py [--steps 200]
"""
import argparse
import tempfile

import jax

from repro.configs.base import AttnConfig, ModelConfig
from repro.configs.registry import SMOKE_RETRO
from repro.data.pipeline import lm_batches
from repro.training import checkpoint as ckpt
from repro.training.optimizer import AdamWConfig
from repro.training.train_loop import (TrainState, init_train_state,
                                       make_train_step, train)

CFG = ModelConfig(
    arch_id="train-small", family="dense", n_layers=4, d_model=256,
    d_ff=1024, vocab=4096,
    attn=AttnConfig(n_heads=8, n_kv_heads=4, head_dim=32),
    dtype="float32", retro=SMOKE_RETRO)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    args = ap.parse_args()

    n_params = CFG.param_count()
    print(f"model: {n_params / 1e6:.1f}M params")
    data = lm_batches(CFG, batch=8, seq=256, seed=0)
    opt = AdamWConfig(lr=3e-3, warmup_steps=10, total_steps=args.steps)

    state, hist = train(CFG, opt, data, args.steps, log_every=20,
                        callback=lambda s, m: print(
                            f"step {s:4d} loss {m['loss']:.4f} "
                            f"lr {m['lr']:.2e} gnorm {m['grad_norm']:.2f}"))
    print(f"loss: {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f}")

    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, state, step=args.steps)
        restored, step = ckpt.restore(d, state)
        print(f"checkpoint roundtrip OK at step {step}")
        # continue training from the restored state
        step_fn = jax.jit(make_train_step(CFG, opt))
        st = TrainState(*restored)
        for i in range(5):
            st, m = step_fn(st, next(data))
        print(f"resumed +5 steps, loss {float(m['loss']):.4f}")


if __name__ == "__main__":
    main()
