"""End-to-end serving driver: batched long-context requests through the
serving engine with the RetroInfer runtime, plus the host-offload wave buffer
(paper's GPU-CPU configuration) demonstrated on the same trace.

    PYTHONPATH=src python examples/serve_longcontext.py
"""
import time

import jax
import numpy as np

from repro.configs.base import AttnConfig, ModelConfig, RetroConfig
from repro.core.wave_buffer import WaveBuffer
from repro.models import model as M
from repro.serving.engine import Request, ServeEngine

RETRO = RetroConfig(avg_cluster=16, cluster_cap=32, prefill_segment=512,
                    update_segment=256, sink=4, local=64, kmeans_iters=5)

CFG = ModelConfig(
    arch_id="serve-demo", family="dense", n_layers=4, d_model=256, d_ff=512,
    vocab=2048, attn=AttnConfig(n_heads=8, n_kv_heads=4, head_dim=32),
    dtype="float32", retro=RETRO)


def main():
    S, B, new_tokens = 4096, 2, 24
    params = M.init_params(CFG, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    for runtime, offload in (("retro", False), ("retro", True),
                             ("full", False)):
        engine = ServeEngine(CFG, params, runtime=runtime, gen_headroom=512,
                             offload=offload, cache_frac=0.2)
        reqs = [Request(prompt=rng.integers(0, CFG.vocab, S).astype(np.int32),
                        max_new_tokens=new_tokens) for _ in range(2 * B)]
        t0 = time.perf_counter()
        m = engine.serve(reqs, batch_size=B)
        dt = time.perf_counter() - t0
        tag = "retro+off" if offload else runtime
        print(f"[{tag:9s}] {len(reqs)} reqs x {S} ctx -> "
              f"{new_tokens} new tokens each: {dt:.1f}s total, "
              f"decode {m.decode_tps:.1f} tok/s, "
              f"slot occupancy {m.slot_occupancy:.2f}"
              + (f", cache hit {m.cache_hit_ratio:.3f}, "
                 f"link {m.bytes_over_link / 2**20:.1f} MiB" if offload
                 else ""))

    # --- host-offload configuration: device block cache over host KV blocks
    # (clamped >= 1: a tiny fractional sizing must degrade to a one-slot
    # cache, not a zero-slot pass-through)
    n_clusters, payload = 2048, 2 * 32 * 32  # K+V block of one cluster
    host_kv = rng.standard_normal((n_clusters, payload)).astype(np.float32)
    buf = WaveBuffer(host_kv, cache_clusters=max(1, int(0.05 * n_clusters)))
    working = rng.choice(n_clusters, 48, replace=False)
    for step in range(256):
        if step % 16 == 0:
            working[rng.integers(0, 48, 3)] = rng.integers(0, n_clusters, 3)
        buf.assemble(rng.choice(working, 24, replace=False))
        buf.apply_updates()          # async in the paper; between steps here
    s = buf.stats
    print(f"[offload] block-cache hit ratio {s.hit_ratio:.3f}; "
          f"link traffic {s.bytes_over_link / 2**20:.1f} MiB vs "
          f"{(s.bytes_over_link + s.bytes_from_cache) / 2**20:.1f} MiB "
          f"without cache")


if __name__ == "__main__":
    main()
