"""Quickstart: build a wave index over a synthetic KV cache and compare
tripartite wave attention against full attention.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import RetroConfig
from repro.core.attention import (DenseCache, full_attention_decode,
                                  wave_attention_decode)
from repro.core.wave_index import max_clusters, prefill_build
from repro.core.zones import plan_zones
from repro.data.pipeline import clustered_keys


def main():
    n, hd = 8192, 64
    retro = RetroConfig(avg_cluster=16, cluster_cap=32, prefill_segment=1024,
                        update_segment=256, sink=4, local=64, kmeans_iters=8)

    # Structured key field: scattered "important" spans (paper Fig. 3).
    keys, q, hot = clustered_keys(n, hd, n_hot=8, seed=0)
    vals = np.random.default_rng(1).standard_normal((n, hd)).astype(np.float32)

    # 1. Prefill: segmented spherical k-means -> wave index
    k = jnp.asarray(keys)[None, :, None, :]          # (B=1, n, H=1, hd)
    v = jnp.asarray(vals)[None, :, None, :]
    state = prefill_build(k, v, retro, max_clusters(n, retro, 256),
                          dtype=jnp.float32)
    print(f"wave index: {int(state.n_clusters[0])} clusters over {n} tokens "
          f"({int(state.stored.sum())} stored, "
          f"{int(state.size.sum()) - int(state.stored.sum())} overflow)")

    # 2. One decode step: steady + retrieval + estimation zones
    qj = jnp.asarray(q)[None, None, :]
    plan = plan_zones(n, retro, 256)
    out = wave_attention_decode(qj, state, retro, plan)
    print(f"zones: steady={plan.sink}+{plan.local_buf}, retrieval r={plan.r} "
          f"clusters (~{plan.r * retro.cluster_cap} tokens), "
          f"estimation e={plan.e} clusters")

    # 3. Compare with full attention
    cache = DenseCache(jnp.swapaxes(k, 1, 2), jnp.swapaxes(v, 1, 2),
                       jnp.full((k.shape[0],), n, jnp.int32))
    ref = full_attention_decode(qj, cache)
    rel = float(jnp.linalg.norm(out.out - ref) / jnp.linalg.norm(ref))

    pos = np.asarray(state.pos_store[0, 0])[np.asarray(out.retrieved)[0, 0]]
    sel = np.zeros(n, bool)
    sel[pos[pos >= 0]] = True
    print(f"relative error vs full attention: {rel:.4f}")
    print(f"hot-token recall through retrieval zone: {sel[hot].mean():.3f}")
    print(f"tokens touched: {sel.sum() + plan.sink + plan.local_buf} "
          f"of {n} ({100 * (sel.sum() + 68) / n:.1f}%)")


if __name__ == "__main__":
    main()
