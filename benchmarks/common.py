"""Benchmark helpers: timing + CSV emission.

Every benchmark prints ``name,us_per_call,derived`` rows (derived = the
figure-specific metric, e.g. accuracy or bytes ratio).
"""
from __future__ import annotations

import time
from typing import Callable

import jax

ROWS = []


def timeit(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall time per call in microseconds."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6


def cost_bytes(compiled) -> float:
    """XLA 'bytes accessed' of a ``jit(...).lower(...).compile()`` result
    (jax returns a dict, or a list of per-device dicts on some versions)."""
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    return float(ca.get("bytes accessed", 0.0))


def emit(name: str, us: float, derived) -> None:
    row = f"{name},{us:.1f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


def tiny_retro(**kw):
    from repro.configs.base import RetroConfig
    base = dict(avg_cluster=16, cluster_cap=32, prefill_segment=512,
                update_segment=256, sink=4, local=64, kmeans_iters=5)
    base.update(kw)
    return RetroConfig(**base)
