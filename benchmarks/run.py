# One benchmark per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
from __future__ import annotations

import sys
import time


def main() -> None:
    from benchmarks import (bench_accuracy_budget, bench_cache,
                            bench_estimation, bench_longgen, bench_niah,
                            bench_prefill, bench_segment_size,
                            bench_throughput)
    suites = [
        ("fig18_accuracy_vs_budget", bench_accuracy_budget.run),
        ("fig19a_estimation", bench_estimation.run),
        ("fig19b_segment_size", bench_segment_size.run),
        ("fig13_decode_throughput", bench_throughput.run),
        ("fig16_wave_buffer", bench_cache.run),
        ("fig15_prefill_overhead", bench_prefill.run),
        ("fig17b_long_generation", bench_longgen.run),
        ("fig10_niah_trained_model", bench_niah.run),
        ("ragged_continuous_serving", bench_throughput.run_ragged_continuous),
    ]
    only = sys.argv[1] if len(sys.argv) > 1 else None
    print("name,us_per_call,derived")
    for name, fn in suites:
        if only and only not in name:
            continue
        t0 = time.time()
        fn()
        print(f"# {name} done in {time.time() - t0:.1f}s", flush=True)


if __name__ == '__main__':
    main()
