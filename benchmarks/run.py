# One benchmark per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
#
# ``--quick`` runs the continuous-serving smoke comparison (chunked vs
# blocking admission on the same ragged queue), the jnp-vs-fused decode
# attention comparison (per-step latency p50/p99 + cost_analysis bytes), the
# host-offload serving comparison (serve-level wave-buffer hit ratio /
# link traffic at several cache fractions, outputs vs the direct store), and
# the retrofault degradation trajectory (decode tps + degraded-step fraction
# under seeded fault schedules at rates {0, 0.05, 0.2}) and writes them to a
# ``BENCH_throughput.json`` artifact so the perf trajectory is recorded per
# PR. It also runs the fig18 fidelity snapshot (attention rel-err at the
# paper budget with/without estimation, hot-token recall, estimation-zone
# Jensen logit error) into a ``BENCH_accuracy.json`` artifact.
from __future__ import annotations

import json
import sys
import time


def main() -> None:
    args = [a for a in sys.argv[1:] if a != "--quick"]
    quick = "--quick" in sys.argv[1:]
    if quick:
        from benchmarks import bench_throughput
        print("name,us_per_call,derived")
        t0 = time.time()
        res = bench_throughput.compare_admission(quick=True)
        res["attn_impl"] = bench_throughput.compare_attn_impl(quick=True)
        res["offload"] = bench_throughput.compare_offload(quick=True)
        res["degradation"] = bench_throughput.compare_degradation(quick=True)
        with open("BENCH_throughput.json", "w") as f:
            json.dump(res, f, indent=2)
            f.write("\n")
        print(f"# quick smoke done in {time.time() - t0:.1f}s "
              f"-> BENCH_throughput.json", flush=True)
        print(json.dumps(res, indent=2))
        assert res["outputs_equal"], \
            "chunked admission changed outputs vs blocking"
        assert res["attn_impl"]["outputs_equal"], \
            "fused attention changed outputs vs jnp"
        assert res["attn_impl"]["bytes_drop_frac"] > 0, \
            "fused decode step did not reduce bytes accessed"
        assert res["offload"]["outputs_equal"], \
            "host-offload serving changed outputs vs the direct store"
        fr = res["offload"]["cache_fracs"]
        assert all(v["bytes_over_link"] > 0 for v in fr.values()), \
            "offload serving recorded no link traffic"
        assert all(v["offload_vs_direct_tps"] > 0 for v in fr.values()), \
            "offload comparison missing the offload-vs-direct tps ratio"
        assert res["degradation"]["outputs_equal"], \
            "zero-rate fault schedule changed outputs vs fault-free offload"
        assert res["degradation"]["completes_under_faults"], \
            "a faulted serve run dropped tokens (request did not complete)"
        dr = res["degradation"]["fault_rates"]
        assert dr["0.0"]["degraded_steps"] == 0, \
            "zero-rate fault schedule recorded degraded steps"
        assert all(v["decode_tps"] > 0 for v in dr.values()), \
            "degradation comparison missing decode tps"

        from benchmarks import bench_accuracy_budget
        acc = bench_accuracy_budget.compare_accuracy(quick=True)
        with open("BENCH_accuracy.json", "w") as f:
            json.dump(acc, f, indent=2)
            f.write("\n")
        print("# accuracy snapshot -> BENCH_accuracy.json", flush=True)
        print(json.dumps(acc, indent=2))
        assert acc["rel_err_est"] < acc["rel_err_noest"], \
            "estimation zone did not improve fidelity at the paper budget"
        assert acc["at_frac_0.1"]["rel_err_est"] < acc["rel_err_est"], \
            "attention error did not shrink with a larger retrieval budget"
        assert acc["at_frac_0.1"]["hot_recall"] >= acc["hot_recall"] > 0, \
            "hot-token recall not positive / not monotone in budget"
        assert acc["est_zone_max_abs_logit_err"] < 2.0, \
            "estimation-zone Jensen logit error blew past the Eq.2-4 regime"
        return

    from benchmarks import (bench_accuracy_budget, bench_cache,
                            bench_estimation, bench_longgen, bench_niah,
                            bench_prefill, bench_segment_size,
                            bench_throughput)
    suites = [
        ("fig18_accuracy_vs_budget", bench_accuracy_budget.run),
        ("fig19a_estimation", bench_estimation.run),
        ("fig19b_segment_size", bench_segment_size.run),
        ("fig13_decode_throughput", bench_throughput.run),
        ("attn_impl_jnp_vs_fused", bench_throughput.run_attn_impl),
        ("fig16_wave_buffer", bench_cache.run),
        ("fig16_serve_offload", bench_throughput.run_offload),
        ("retrofault_degradation", bench_throughput.run_degradation),
        ("fig15_prefill_overhead", bench_prefill.run),
        ("fig17b_long_generation", bench_longgen.run),
        ("fig10_niah_trained_model", bench_niah.run),
        ("ragged_continuous_serving", bench_throughput.run_ragged_continuous),
    ]
    only = args[0] if args else None
    print("name,us_per_call,derived")
    for name, fn in suites:
        if only and only not in name:
            continue
        t0 = time.time()
        fn()
        print(f"# {name} done in {time.time() - t0:.1f}s", flush=True)


if __name__ == '__main__':
    main()
