"""Paper Fig. 19(b): index build time vs clustering quality per segment size.

The paper: 8K segments keep recall within 1% of global k-means at ~80% lower
build cost. We sweep segment sizes on an 8K context and report build time and
recall@100 of the retrieval zone.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit
from repro.core.clustering import segmented_cluster
from repro.data.pipeline import clustered_keys


def run():
    n, hd = 8192, 64
    keys, q, _ = clustered_keys(n, hd, n_hot=8, seed=7)
    kj = jnp.asarray(keys)
    vv = jnp.zeros_like(kj)
    pos = jnp.arange(n, dtype=jnp.int32)
    scores = keys @ q
    top100 = np.argsort(-scores)[:100]

    for seg in (512, 1024, 2048, 4096, 8192):   # 8192 == global k-means here
        fn = jax.jit(lambda k, v: segmented_cluster(
            k, v, pos, seg, 16, 32, 5, True))
        us = timeit(fn, kj, vv, iters=3)
        res = fn(kj, vv)
        csc = np.asarray(res.centroid) @ q
        r = max(1, int(0.1 * n // 16))
        order = np.argsort(-csc)[:r]
        p = np.asarray(res.pos_store)[order].reshape(-1)
        sel = np.zeros(n, bool)
        sel[p[p >= 0]] = True
        recall = sel[top100].mean()
        emit(f"fig19b_segment{seg}", us, f"recall@100={recall:.3f}")


if __name__ == "__main__":
    run()
