"""Paper Fig. 19(a): the estimation zone's contribution to fidelity.

Sweeps the estimation budget at fixed (small) retrieval budget; the paper
shows estimation recovers up to +20% task accuracy at no PCIe cost. Here the
metric is attention-output relative error on structured keys.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit, tiny_retro
from repro.core.attention import (DenseCache, full_attention_decode,
                                  wave_attention_decode)
from repro.core.wave_index import max_clusters, prefill_build
from repro.core.zones import plan_zones
from repro.data.pipeline import clustered_keys


def run():
    n, hd = 8192, 64
    retro = tiny_retro()
    keys, q, _ = clustered_keys(n, hd, n_hot=8, seed=3)
    vals = np.random.default_rng(4).standard_normal((n, hd)).astype(np.float32)
    kj = jnp.asarray(keys)[None, :, None, :]
    vj = jnp.asarray(vals)[None, :, None, :]
    state = prefill_build(kj, vj, retro, max_clusters(n, retro, 256),
                          dtype=jnp.float32)
    cache = DenseCache(jnp.swapaxes(kj, 1, 2), jnp.swapaxes(vj, 1, 2),
                       jnp.full((kj.shape[0],), n, jnp.int32))
    qj = jnp.asarray(q)[None, None, :]
    ref = np.asarray(full_attention_decode(qj, cache))

    m = int(state.n_clusters[0])
    r = max(1, int(m * 0.018))
    for efrac in (0.0, 0.05, 0.116, 0.232, 0.5):
        e = int(m * efrac)
        plan = plan_zones(n, retro, 256)._replace(r=r, e=max(e, 0))
        fn = jax.jit(lambda q, s: wave_attention_decode(
            q, s, retro, plan, use_estimation=e > 0).out)
        us = timeit(fn, qj, state)
        out = np.asarray(fn(qj, state))
        rel = np.linalg.norm(out - ref) / np.linalg.norm(ref)
        emit(f"fig19a_est{efrac}", us, f"rel_err={rel:.4f}")


if __name__ == "__main__":
    run()
