"""Paper Fig. 16: wave-buffer design ablation (host-offload configuration).

Base (no device cache, every retrieved cluster crosses the link) vs
+ block cache (LRU) vs + async update. Metrics: link traffic per step and
control-plane time per step on temporally-local cluster request traces.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.core.wave_buffer import WaveBuffer


def make_trace(n_clusters=4096, steps=300, working=64, req=32, drift=4,
               seed=0):
    rng = np.random.default_rng(seed)
    ws = rng.choice(n_clusters, size=working, replace=False)
    out = []
    for s in range(steps):
        if s % 8 == 0 and s:
            ws[rng.integers(0, working, drift)] = rng.integers(
                0, n_clusters, drift)
        out.append(rng.choice(ws, size=req, replace=False))
    return out


def cache_size(frac: float, n: int) -> int:
    """Cache slots for a fractional sizing — clamped to >= 1 so tiny
    ``int(frac * n)`` configs degrade to a one-slot cache instead of the
    zero-slot pass-through."""
    return max(1, int(frac * n))


def run():
    n, payload = 4096, 2048                       # 2KB blocks (paper default)
    host = np.zeros((n, payload // 4), np.float32)
    trace = make_trace(n)

    # Base: no cache — all bytes over the link every step
    base_link = len(trace) * trace[0].size * host[0].nbytes
    t0 = time.perf_counter()
    for ids in trace:
        _ = host[ids]                             # direct host fetch
    emit("fig16_base_no_cache", (time.perf_counter() - t0) / len(trace) * 1e6,
         f"hit=0.000;link_bytes={base_link}")

    # + block cache, update performed synchronously on the critical path
    buf = WaveBuffer(host, cache_clusters=cache_size(0.05, n), policy="lru")
    t0 = time.perf_counter()
    for ids in trace:
        buf.assemble(ids)
        buf.apply_updates()                       # ON the critical path
    dt = (time.perf_counter() - t0) / len(trace) * 1e6
    emit("fig16_cache_sync_update", dt,
         f"hit={buf.stats.hit_ratio:.3f};link_bytes="
         f"{buf.stats.bytes_over_link};base_link_bytes={base_link}")

    # + asynchronous update: only the access is on the critical path
    buf = WaveBuffer(host, cache_clusters=cache_size(0.05, n), policy="lru")
    t_access = 0.0
    for ids in trace:
        t0 = time.perf_counter()
        buf.assemble(ids)
        t_access += time.perf_counter() - t0
        buf.apply_updates()                       # off critical path
    emit("fig16_cache_async_update", t_access / len(trace) * 1e6,
         f"hit={buf.stats.hit_ratio:.3f};link_bytes={buf.stats.bytes_over_link}"
         f";reduction={base_link / max(buf.stats.bytes_over_link, 1):.2f}x")

    # replacement-policy ablation (paper: "explored several cache policies,
    # selected LRU as default due to its best performance")
    for policy in ("lru", "clock", "fifo"):
        buf = WaveBuffer(host, cache_clusters=cache_size(0.05, n), policy=policy)
        for ids in trace:
            buf.assemble(ids)
            buf.apply_updates()
        emit(f"fig16_policy_{policy}", 0.0,
             f"hit={buf.stats.hit_ratio:.3f};link_bytes="
             f"{buf.stats.bytes_over_link}")


if __name__ == "__main__":
    run()
