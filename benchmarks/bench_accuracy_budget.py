"""Paper Fig. 18(a-b): task fidelity vs retrieval budget.

On structured key fields (scattered important spans — the dynamic-sparsity
structure of paper Fig. 3) we sweep the retrieval budget and report (a) the
attention-output relative error vs full attention and (b) hot-token recall.
The paper's finding to reproduce: ~1.8% retrieval budget + estimation zone
reaches full-attention-level fidelity; without estimation it does not.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit, tiny_retro
from repro.core.attention import (DenseCache, full_attention_decode,
                                  wave_attention_decode)
from repro.core.wave_index import max_clusters, prefill_build
from repro.core.zones import plan_zones
from repro.data.pipeline import clustered_keys


def run():
    n, hd = 8192, 64
    retro = tiny_retro()
    keys, q, hot = clustered_keys(n, hd, n_hot=8, seed=0)
    rng = np.random.default_rng(1)
    vals = rng.standard_normal((n, hd)).astype(np.float32)
    kj = jnp.asarray(keys)[None, :, None, :]
    vj = jnp.asarray(vals)[None, :, None, :]
    state = prefill_build(kj, vj, retro, max_clusters(n, retro, 256),
                          dtype=jnp.float32)
    cache = DenseCache(jnp.swapaxes(kj, 1, 2), jnp.swapaxes(vj, 1, 2),
                       jnp.full((kj.shape[0],), n, jnp.int32))
    qj = jnp.asarray(q)[None, None, :]
    ref = np.asarray(full_attention_decode(qj, cache))

    m = int(state.n_clusters[0])
    plan0 = plan_zones(n, retro, 256)
    for frac in (0.005, 0.018, 0.05, 0.1, 0.25):
        r = max(1, int(m * frac))
        for est in (True, False):
            plan = plan0._replace(r=r, e=plan0.e if est else 0)
            fn = jax.jit(lambda q, s: wave_attention_decode(
                q, s, retro, plan, use_estimation=est).out)
            us = timeit(fn, qj, state)
            out = np.asarray(fn(qj, state))
            rel = np.linalg.norm(out - ref) / np.linalg.norm(ref)
            # hot-token recall through the retrieval zone
            idx = np.asarray(wave_attention_decode(
                qj, state, retro, plan).retrieved)[0, 0]
            pos = np.asarray(state.pos_store[0, 0])[idx].reshape(-1)
            sel = np.zeros(n, bool)
            sel[pos[pos >= 0]] = True
            recall = sel[hot].mean()
            emit(f"fig18_budget_r{frac}_est{int(est)}", us,
                 f"rel_err={rel:.4f};hot_recall={recall:.3f}")


if __name__ == "__main__":
    run()
