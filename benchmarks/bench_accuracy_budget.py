"""Paper Fig. 18(a-b): task fidelity vs retrieval budget.

On structured key fields (scattered important spans — the dynamic-sparsity
structure of paper Fig. 3) we sweep the retrieval budget and report (a) the
attention-output relative error vs full attention and (b) hot-token recall.
The paper's finding to reproduce: ~1.8% retrieval budget + estimation zone
reaches full-attention-level fidelity; without estimation it does not.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit, tiny_retro
from repro.core.attention import (DenseCache, _estimation_zone,
                                  _gather_clusters, full_attention_decode,
                                  rank_clusters, wave_attention_decode)
from repro.core.wave_index import max_clusters, prefill_build
from repro.core.zones import plan_zones
from repro.data.pipeline import clustered_keys


def run():
    n, hd = 8192, 64
    retro = tiny_retro()
    keys, q, hot = clustered_keys(n, hd, n_hot=8, seed=0)
    rng = np.random.default_rng(1)
    vals = rng.standard_normal((n, hd)).astype(np.float32)
    kj = jnp.asarray(keys)[None, :, None, :]
    vj = jnp.asarray(vals)[None, :, None, :]
    state = prefill_build(kj, vj, retro, max_clusters(n, retro, 256),
                          dtype=jnp.float32)
    cache = DenseCache(jnp.swapaxes(kj, 1, 2), jnp.swapaxes(vj, 1, 2),
                       jnp.full((kj.shape[0],), n, jnp.int32))
    qj = jnp.asarray(q)[None, None, :]
    ref = np.asarray(full_attention_decode(qj, cache))

    m = int(state.n_clusters[0])
    plan0 = plan_zones(n, retro, 256)
    for frac in (0.005, 0.018, 0.05, 0.1, 0.25):
        r = max(1, int(m * frac))
        for est in (True, False):
            plan = plan0._replace(r=r, e=plan0.e if est else 0)
            fn = jax.jit(lambda q, s: wave_attention_decode(
                q, s, retro, plan, use_estimation=est).out)
            us = timeit(fn, qj, state)
            out = np.asarray(fn(qj, state))
            rel = np.linalg.norm(out - ref) / np.linalg.norm(ref)
            # hot-token recall through the retrieval zone
            idx = np.asarray(wave_attention_decode(
                qj, state, retro, plan).retrieved)[0, 0]
            pos = np.asarray(state.pos_store[0, 0])[idx].reshape(-1)
            sel = np.zeros(n, bool)
            sel[pos[pos >= 0]] = True
            recall = sel[hot].mean()
            emit(f"fig18_budget_r{frac}_est{int(est)}", us,
                 f"rel_err={rel:.4f};hot_recall={recall:.3f}")


def compare_accuracy(quick: bool = True) -> dict:
    """Fidelity snapshot at the paper budget, for ``run.py --quick`` →
    ``BENCH_accuracy.json``.

    Three numbers, all from one prefix: (a) Fig. 18(a) attention-output
    relative error vs full attention at ~1.8% retrieval budget, with and
    without the estimation zone; (b) Fig. 18(b) hot-token recall through the
    retrieval zone; (c) the estimation-zone Jensen logit error — max over
    live estimation clusters of ``|(cs_i + log s_i) - logsumexp_t(q·k_t)|``,
    the per-cluster gap the paper's Eq. 2-4 accuracy bound controls.
    """
    import math

    n, hd = (4096 if quick else 8192), 64
    retro = tiny_retro()
    keys, q, hot = clustered_keys(n, hd, n_hot=8, seed=0)
    rng = np.random.default_rng(1)
    vals = rng.standard_normal((n, hd)).astype(np.float32)
    kj = jnp.asarray(keys)[None, :, None, :]
    vj = jnp.asarray(vals)[None, :, None, :]
    state = prefill_build(kj, vj, retro, max_clusters(n, retro, 256),
                          dtype=jnp.float32)
    cache = DenseCache(jnp.swapaxes(kj, 1, 2), jnp.swapaxes(vj, 1, 2),
                       jnp.full((kj.shape[0],), n, jnp.int32))
    qj = jnp.asarray(q)[None, None, :]
    ref = np.asarray(full_attention_decode(qj, cache))

    m = int(state.n_clusters[0])
    plan0 = plan_zones(n, retro, 256)

    def _point(frac):
        plan = plan0._replace(r=max(1, int(m * frac)))
        rel = {}
        for est in (True, False):
            p = plan if est else plan._replace(e=0)
            o = np.asarray(wave_attention_decode(
                qj, state, retro, p, use_estimation=est).out)
            rel[est] = float(np.linalg.norm(o - ref) / np.linalg.norm(ref))
        res = wave_attention_decode(qj, state, retro, plan)
        pos = np.asarray(state.pos_store[0, 0])[
            np.asarray(res.retrieved)[0, 0]].reshape(-1)
        sel = np.zeros(n, bool)
        sel[pos[pos >= 0]] = True
        return plan, rel, float(sel[hot].mean())

    frac = 0.018
    plan, rel, recall = _point(frac)
    _, rel_hi, recall_hi = _point(0.1)

    # (c) estimation-zone Jensen logit error against the true per-cluster
    # logsumexp over the stored tokens (no overflow correction: the metric
    # is the raw ``cs + log s`` estimate the kernel's est_logit path uses).
    qg = qj.reshape(1, 1, 1, hd)
    scale = 1.0 / math.sqrt(hd)
    cs, idx_re = rank_clusters(qg, state, plan, None, None)
    idx_e = idx_re[:, :, plan.r:]
    est_logit, _, _ = _estimation_zone(
        state, cs, idx_re[:, :, :plan.r], idx_e,
        use_estimation=True, overflow_correction=False)
    k_e, _, pos_e = _gather_clusters(state, idx_e)         # (B,H,e,cap,hd)
    tok = jnp.einsum("bhgd,bhecd->bhgec", qg.astype(jnp.float32),
                     k_e.astype(jnp.float32),
                     preferred_element_type=jnp.float32) * scale
    tok = jnp.where((pos_e >= 0)[:, :, None, :, :], tok, -1e30)
    true_logit = jax.nn.logsumexp(tok, axis=-1)            # (B,H,G,e)
    live = np.asarray(
        jnp.take_along_axis(state.size, idx_e, axis=2) > 0)[:, :, None, :]
    gap = np.abs(np.asarray(est_logit - true_logit))[live]
    max_err = float(gap.max()) if gap.size else 0.0
    mean_err = float(gap.mean()) if gap.size else 0.0

    out = {"n": n, "budget_frac": frac, "retrieval_clusters": int(plan.r),
           "estimation_clusters": int(idx_e.shape[2]),
           "rel_err_est": rel[True], "rel_err_noest": rel[False],
           "hot_recall": recall,
           "at_frac_0.1": {"rel_err_est": rel_hi[True],
                           "hot_recall": recall_hi},
           "est_zone_max_abs_logit_err": max_err,
           "est_zone_mean_abs_logit_err": mean_err}
    emit(f"fig18_quick_r{frac}", 0.0,
         f"rel_err_est={rel[True]:.4f};rel_err_noest={rel[False]:.4f};"
         f"hot_recall={recall:.3f};est_logit_err={max_err:.3f}")
    return out


if __name__ == "__main__":
    run()
