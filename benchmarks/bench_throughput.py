"""Paper Fig. 13: decode throughput, RetroInfer vs full attention, across
context lengths.

CPU wall-clock at reduced scale + the structural metric that transfers to
TPU: KV bytes touched per decode step (the roofline memory term driver).
The paper's 4.4x at 120K comes precisely from this bytes reduction.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import cost_bytes, emit, timeit, tiny_retro
from repro.core.attention import (DenseCache, dense_cache_append,
                                  full_attention_decode,
                                  wave_attention_decode)
from repro.core.wave_index import append_token, max_clusters, prefill_build
from repro.core.zones import plan_zones


def bytes_touched_full(n, H, hd, itemsize=4):
    return 2 * n * H * hd * itemsize                     # read all K and V


def bytes_touched_retro(plan, retro, H, hd, m, itemsize=4):
    steady = plan.sink + plan.local_buf
    exact = steady + plan.r * retro.cluster_cap
    meta = m * hd + m                                    # centroids + sizes
    est = plan.e * hd                                    # value sums
    return (2 * exact * H * hd + meta + est) * itemsize


def _ragged_setup(quick: bool = False, retrieval_frac: float = 0.018):
    """Tiny ragged-arrival serving scenario shared by both admission modes:
    a queue longer than the slot count, so admissions keep happening while
    other requests decode (the interference the chunked scheduler targets).
    ``retrieval_frac`` is raised by the offload scenario so the per-step
    working set actually exceeds the small cache fractions."""
    import jax as _jax
    from repro.configs.base import AttnConfig, ModelConfig, RetroConfig
    from repro.models import model as M

    retro = RetroConfig(avg_cluster=8, cluster_cap=64, prefill_segment=64,
                        update_segment=32, sink=4, local=32, kmeans_iters=3,
                        retrieval_frac=retrieval_frac)
    cfg = ModelConfig(
        arch_id="ragged-bench", family="dense", n_layers=2, d_model=64,
        d_ff=128, vocab=512,
        attn=AttnConfig(n_heads=4, n_kv_heads=2, head_dim=16),
        dtype="float32", retro=retro)
    params = M.init_params(cfg, _jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    # prompts many chunks long: a blocking admission stalls decode for the
    # whole prefill, a chunked one for a single 64-token chunk
    lens = (768, 512, 704, 640) if quick else (768, 512, 704, 640, 768, 576)
    # alternating budgets keep a long-running request decoding through every
    # admission, so its inter-token gaps actually witness the stall
    news = [(10 + 6 * (i % 2)) if quick else (8 + 6 * (i % 3))
            for i in range(len(lens))]
    prompts = [rng.integers(0, cfg.vocab, L).astype(np.int32) for L in lens]
    return cfg, params, prompts, news


def _serve_ragged(cfg, params, prompts, news, mode: str, warm: bool = True):
    """Serve the scenario under one admission mode. ``warm=True`` runs the
    queue once first so compile time is excluded from latency percentiles
    (the blocking mode would otherwise also pay per-bucket prefill compiles
    mid-run — real, but not the steady-state interference being measured)."""
    from repro.serving.engine import Request, ServeEngine

    eng = ServeEngine(cfg, params, runtime="retro", gen_headroom=256,
                      max_context=768, admission=mode, prefill_chunk=64)
    for _ in range(2 if warm else 1):
        reqs = [Request(prompt=p.copy(), max_new_tokens=n)
                for p, n in zip(prompts, news)]
        m = eng.serve(reqs, batch_size=2)
    return m, [r.out_tokens for r in reqs]


def compare_admission(quick: bool = False) -> dict:
    """Chunked vs blocking admission on the same ragged queue: same outputs,
    lower p99 inter-token latency under concurrent admission (chunked never
    stalls decode longer than one prefill chunk). ``benchmarks/run.py
    --quick`` merges the result into the BENCH_throughput.json artifact."""
    cfg, params, prompts, news = _ragged_setup(quick)
    result = {"scenario": "ragged_continuous", "slots": 2,
              "requests": len(prompts), "prefill_chunk": 64, "modes": {}}
    outs = {}
    for mode in ("blocking", "chunked"):
        m, outs[mode] = _serve_ragged(cfg, params, prompts, news, mode)
        result["modes"][mode] = {
            "decode_tps": round(m.decode_tps, 1),
            "itl_p50_ms": round(m.itl_p50_s * 1e3, 3),
            "itl_p99_ms": round(m.itl_p99_s * 1e3, 3),
            "ttft_p99_s": round(m.ttft_p99_s, 4),
            "mean_ttft_s": round(float(np.mean(m.ttft_s)), 4),
            "tokens_out": m.tokens_out,
            "slot_occupancy": round(m.slot_occupancy, 3),
        }
        emit(f"ragged_continuous_{mode}",
             m.decode_s / max(m.tokens_out, 1) * 1e6,
             f"decode_tps={m.decode_tps:.1f};tokens={m.tokens_out};"
             f"occupancy={m.slot_occupancy:.2f};"
             f"itl_p99_ms={m.itl_p99_s * 1e3:.2f};"
             f"mean_ttft_s={np.mean(m.ttft_s):.2f}")
    result["outputs_equal"] = outs["blocking"] == outs["chunked"]
    b99 = result["modes"]["blocking"]["itl_p99_ms"]
    c99 = result["modes"]["chunked"]["itl_p99_ms"]
    result["itl_p99_blocking_over_chunked"] = \
        round(b99 / c99, 2) if c99 > 0 else None
    return result


def compare_offload(quick: bool = False) -> dict:
    """Host-offload serving (wave buffer in the decode loop) vs the
    direct-store path, at >= 2 device-cache fractions: token-for-token equal
    outputs plus the serve-level Fig. 16 trajectory (hit ratio, bytes over
    the link, pending hits). ``benchmarks/run.py --quick`` merges the result
    into BENCH_throughput.json."""
    # retrieval-heavy plan (r ~ 30 clusters/step at 768 ctx): the small cache
    # fractions then sit well under the per-step working set, so the
    # trajectory actually spans eviction pressure -> high reuse
    cfg, params, prompts, news = _ragged_setup(quick, retrieval_frac=0.3)
    if quick:       # offload decode syncs per layer: trim the quick queue
        prompts, news = prompts[:3], news[:3]

    def serve(offload, frac):
        from repro.serving.engine import Request, ServeEngine
        eng = ServeEngine(cfg, params, runtime="retro", gen_headroom=256,
                          max_context=768, admission="chunked",
                          prefill_chunk=64, offload=offload, cache_frac=frac)
        reqs = [Request(prompt=p.copy(), max_new_tokens=n)
                for p, n in zip(prompts, news)]
        m = eng.serve(reqs, batch_size=2)
        return m, [r.out_tokens for r in reqs]

    m0, ref = serve(False, 0.2)
    result = {"scenario": "ragged_continuous_offload", "slots": 2,
              "requests": len(prompts),
              "direct": {"decode_tps": round(m0.decode_tps, 1),
                         "tokens_out": m0.tokens_out},
              "cache_fracs": {}}
    equal = True
    for frac in (0.05, 0.2, 0.5):
        m, outs = serve(True, frac)
        equal = equal and outs == ref
        result["cache_fracs"][str(frac)] = {
            "hit_ratio": round(m.cache_hit_ratio, 4),
            "effective_hit_ratio": round(m.effective_cache_hit_ratio, 4),
            "pending_hits": m.cache_pending_hits,
            "bytes_over_link": m.bytes_over_link,
            "bytes_from_cache": m.bytes_from_cache,
            "bytes_from_pending": m.bytes_from_pending,
            "decode_tps": round(m.decode_tps, 1),
            "offload_vs_direct_tps": round(m.decode_tps / m0.decode_tps, 3),
            "tokens_out": m.tokens_out,
        }
        emit(f"offload_cache_frac_{frac}",
             m.decode_s / max(m.tokens_out, 1) * 1e6,
             f"hit={m.cache_hit_ratio:.3f};"
             f"eff_hit={m.effective_cache_hit_ratio:.3f};"
             f"link_bytes={m.bytes_over_link};"
             f"pending_hits={m.cache_pending_hits}")
    result["outputs_equal"] = equal
    return result


def run_offload():
    """Host-offload serving trajectory (CSV flavor)."""
    compare_offload(quick=False)


def compare_degradation(quick: bool = False) -> dict:
    """retrofault degradation trajectory: offload serving under a seeded
    transient-fault schedule at rates {0, 0.05, 0.2} with no retries and a
    tight fetch deadline, so failed fetches degrade (masked out of the
    retrieval zone, covered by the estimation zone) instead of stalling.
    Records decode tps and the degraded-step fraction per rate; at rate 0
    the outputs must equal the fault-free run token-for-token."""
    cfg, params, prompts, news = _ragged_setup(quick, retrieval_frac=0.3)
    if quick:       # offload decode syncs per layer: trim the quick queue
        prompts, news = prompts[:3], news[:3]

    def serve(profile):
        from repro.serving.engine import Request, ServeEngine
        eng = ServeEngine(cfg, params, runtime="retro", gen_headroom=256,
                          max_context=768, admission="chunked",
                          prefill_chunk=64, offload=True, cache_frac=0.2,
                          fault_profile=profile, fetch_retries=0,
                          fetch_deadline_s=0.01)
        reqs = [Request(prompt=p.copy(), max_new_tokens=n)
                for p, n in zip(prompts, news)]
        m = eng.serve(reqs, batch_size=2)
        return m, [r.out_tokens for r in reqs]

    ref_m, ref = serve(None)          # fault-free offload baseline
    result = {"scenario": "ragged_continuous_degradation", "slots": 2,
              "requests": len(prompts),
              "baseline": {"decode_tps": round(ref_m.decode_tps, 1),
                           "tokens_out": ref_m.tokens_out},
              "fault_rates": {}}
    for rate in (0.0, 0.05, 0.2):
        m, outs = serve(f"transient={rate},spike={rate},seed=17")
        degraded_frac = m.degraded_steps / max(m.steps, 1)
        result["fault_rates"][str(rate)] = {
            "decode_tps": round(m.decode_tps, 1),
            "degraded_steps": m.degraded_steps,
            "degraded_step_frac": round(degraded_frac, 4),
            "dropped_cluster_steps": m.dropped_cluster_steps,
            "faults": m.cache_faults,
            "failed_fetches": m.cache_failed_fetches,
            "tokens_out": m.tokens_out,
            "outputs_equal_baseline": outs == ref,
        }
        emit(f"degradation_fault_rate_{rate}",
             m.decode_s / max(m.tokens_out, 1) * 1e6,
             f"degraded_frac={degraded_frac:.3f};"
             f"faults={m.cache_faults};"
             f"failed={m.cache_failed_fetches}")
    result["outputs_equal"] = \
        result["fault_rates"]["0.0"]["outputs_equal_baseline"]
    result["completes_under_faults"] = all(
        v["tokens_out"] == ref_m.tokens_out
        for v in result["fault_rates"].values())
    return result


def run_degradation():
    """retrofault degradation trajectory (CSV flavor)."""
    compare_degradation(quick=False)


def compare_attn_impl(quick: bool = False) -> dict:
    """jnp vs fused (gather-free paged kernel) decode attention.

    Measures the jitted hot-path decode step (``decode_step_split`` with
    ``unroll=True`` — the engine-perf measurement vehicle of this repo's
    §Perf iterations, which reads the cold cluster stores in place):
    per-step wall-clock p50/p99 and XLA ``cost_analysis`` bytes-accessed,
    plus greedy token-for-token equality between the two impls. The fused
    path eliminates the (B, H, r, cap, hd) cluster gather temp and the
    execution-buffer concat, so its bytes-accessed drops — most visibly when
    the retrieval zone covers a large cluster fraction (the full-store gather
    charge is amortized; on TPU the kernel reads only the r blocks).
    """
    from repro.configs.base import AttnConfig, InputShape, ModelConfig, RetroConfig
    from repro.configs.registry import materialize_batch
    from repro.models import model as M
    from repro.models.transformer import decode_step_split, split_state

    retro = RetroConfig(avg_cluster=16, cluster_cap=32, prefill_segment=256,
                        update_segment=64, sink=4, local=64, kmeans_iters=3,
                        retrieval_frac=0.35, estimation_frac=0.232)
    cfg = ModelConfig(
        arch_id="attn-impl-bench", family="dense", n_layers=2, d_model=64,
        d_ff=128, vocab=256,
        attn=AttnConfig(n_heads=4, n_kv_heads=2, head_dim=32),
        dtype="float32", retro=retro)
    S, B = 2048, 2
    n_steps = 12 if quick else 32
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batch = materialize_batch(cfg, InputShape("p", S, B, "prefill"))
    plan = plan_zones(S, retro, 256)
    _, st = M.apply_prefill(params, cfg, batch, runtime="retro", plan=plan,
                            gen_headroom=256)
    cold, hot0 = split_state(st.kv)
    cold_layers = [jax.tree.map(lambda a, i=i: a[i], cold)
                   for i in range(cfg.n_layers)]

    result = {"scenario": "split_decode_step", "seq_len": S, "batch": B,
              "plan": {"r": plan.r, "e": plan.e, "m_max": plan.m_max,
                       "cluster_cap": retro.cluster_cap}, "modes": {}}
    outs = {}
    for impl in ("jnp", "fused"):
        def dec(p, h, t, *cl, impl=impl):
            return decode_step_split(p, cfg, list(cl), h, t, plan=plan,
                                     unroll=True, attn_impl=impl)
        fn = jax.jit(dec)
        bytes_per_step = cost_bytes(
            fn.lower(params, hot0, jnp.zeros((B,), jnp.int32),
                     *cold_layers).compile())

        hot, tok = hot0, jnp.zeros((B,), jnp.int32)
        lat, toks = [], []
        for i in range(n_steps):
            t0 = time.perf_counter()
            lg, hot = fn(params, hot, tok, *cold_layers)
            tok = jnp.argmax(lg, -1).astype(jnp.int32)
            tok.block_until_ready()
            if i > 0:                       # step 0 pays compile/cache warmup
                lat.append(time.perf_counter() - t0)
            toks.append(np.asarray(tok).tolist())
        outs[impl] = toks
        result["modes"][impl] = {
            "step_p50_ms": round(float(np.percentile(lat, 50)) * 1e3, 3),
            "step_p99_ms": round(float(np.percentile(lat, 99)) * 1e3, 3),
            "bytes_accessed_per_step": int(bytes_per_step),
        }
        emit(f"attn_impl_{impl}", float(np.mean(lat)) * 1e6,
             f"bytes_per_step={int(bytes_per_step)};"
             f"p99_ms={np.percentile(lat, 99) * 1e3:.2f}")
    bj = result["modes"]["jnp"]["bytes_accessed_per_step"]
    bf = result["modes"]["fused"]["bytes_accessed_per_step"]
    result["bytes_drop_frac"] = round(1.0 - bf / bj, 4) if bj else None
    result["outputs_equal"] = outs["jnp"] == outs["fused"]
    return result


def run_attn_impl():
    """jnp-vs-fused decode attention comparison (CSV flavor)."""
    compare_attn_impl(quick=False)


def run_ragged_continuous():
    """Ragged-arrival serving scenario: a mixed queue of prompt lengths with
    staggered generation budgets through the continuous-batching engine,
    under both admission modes — the engine-level metric behind the paper's
    batched-throughput claims (Sec. 6) plus the admission-interference p99."""
    compare_admission(quick=False)


def run():
    hd, H, B = 64, 4, 4
    retro = tiny_retro()
    rng = np.random.default_rng(0)
    for n in (4096, 16384, 65536):
        k = jnp.asarray(rng.standard_normal((B, n, H, hd)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((B, n, H, hd)), jnp.float32)
        q = jnp.asarray(rng.standard_normal((B, 2 * H, hd)), jnp.float32)
        kn = jnp.asarray(rng.standard_normal((B, H, hd)), jnp.float32)

        plan = plan_zones(n, retro, 256)
        state = prefill_build(k, v, retro, max_clusters(n, retro, 256),
                              dtype=jnp.float32)
        m = int(state.n_clusters[0])

        @jax.jit
        def step_retro(q, st, kn):
            st = append_token(st, kn, kn)
            return wave_attention_decode(q, st, retro, plan).out

        cache = DenseCache(jnp.swapaxes(k, 1, 2), jnp.swapaxes(v, 1, 2),
                           jnp.full((B,), n, jnp.int32))

        @jax.jit
        def step_full(q, c, kn):
            c = dense_cache_append(c, kn, kn)
            return full_attention_decode(q, c)

        us_r = timeit(step_retro, q, state, kn)
        us_f = timeit(step_full, q, cache, kn)
        br = bytes_touched_retro(plan, retro, H, hd, m)
        bf = bytes_touched_full(n, H, hd)
        emit(f"fig13_ctx{n}_retro", us_r,
             f"kv_bytes={br};speedup_vs_full={us_f/us_r:.2f}x")
        emit(f"fig13_ctx{n}_full", us_f,
             f"kv_bytes={bf};bytes_reduction={bf/br:.1f}x")


if __name__ == "__main__":
    run()
    run_ragged_continuous()
