"""Paper Fig. 13: decode throughput, RetroInfer vs full attention, across
context lengths.

CPU wall-clock at reduced scale + the structural metric that transfers to
TPU: KV bytes touched per decode step (the roofline memory term driver).
The paper's 4.4x at 120K comes precisely from this bytes reduction.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit, tiny_retro
from repro.core.attention import (DenseCache, dense_cache_append,
                                  full_attention_decode,
                                  wave_attention_decode)
from repro.core.wave_index import append_token, max_clusters, prefill_build
from repro.core.zones import plan_zones


def bytes_touched_full(n, H, hd, itemsize=4):
    return 2 * n * H * hd * itemsize                     # read all K and V


def bytes_touched_retro(plan, retro, H, hd, m, itemsize=4):
    steady = plan.sink + plan.local_buf
    exact = steady + plan.r * retro.cluster_cap
    meta = m * hd + m                                    # centroids + sizes
    est = plan.e * hd                                    # value sums
    return (2 * exact * H * hd + meta + est) * itemsize


def run_ragged_continuous():
    """Ragged-arrival serving scenario: a mixed queue of prompt lengths with
    staggered generation budgets through the continuous-batching engine.
    Emits aggregate decode throughput and slot occupancy — the engine-level
    metric behind the paper's batched-throughput claims (Sec. 6)."""
    import jax as _jax
    from repro.configs.base import AttnConfig, ModelConfig, RetroConfig
    from repro.models import model as M
    from repro.serving.engine import Request, ServeEngine

    retro = RetroConfig(avg_cluster=8, cluster_cap=64, prefill_segment=64,
                        update_segment=32, sink=4, local=32, kmeans_iters=3)
    cfg = ModelConfig(
        arch_id="ragged-bench", family="dense", n_layers=2, d_model=64,
        d_ff=128, vocab=512,
        attn=AttnConfig(n_heads=4, n_kv_heads=2, head_dim=16),
        dtype="float32", retro=retro)
    params = M.init_params(cfg, _jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    lens = (384, 256, 320, 200, 384, 288)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab, L).astype(np.int32),
                    max_new_tokens=8 + 6 * (i % 3))
            for i, L in enumerate(lens)]
    eng = ServeEngine(cfg, params, runtime="retro", gen_headroom=256,
                      max_context=384)
    m = eng.serve(reqs, batch_size=2)
    emit("ragged_continuous_decode", m.decode_s / max(m.tokens_out, 1) * 1e6,
         f"decode_tps={m.decode_tps:.1f};tokens={m.tokens_out};"
         f"occupancy={m.slot_occupancy:.2f};"
         f"mean_ttft_s={np.mean(m.ttft_s):.2f}")


def run():
    hd, H, B = 64, 4, 4
    retro = tiny_retro()
    rng = np.random.default_rng(0)
    for n in (4096, 16384, 65536):
        k = jnp.asarray(rng.standard_normal((B, n, H, hd)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((B, n, H, hd)), jnp.float32)
        q = jnp.asarray(rng.standard_normal((B, 2 * H, hd)), jnp.float32)
        kn = jnp.asarray(rng.standard_normal((B, H, hd)), jnp.float32)

        plan = plan_zones(n, retro, 256)
        state = prefill_build(k, v, retro, max_clusters(n, retro, 256),
                              dtype=jnp.float32)
        m = int(state.n_clusters[0])

        @jax.jit
        def step_retro(q, st, kn):
            st = append_token(st, kn, kn)
            return wave_attention_decode(q, st, retro, plan).out

        cache = DenseCache(jnp.swapaxes(k, 1, 2), jnp.swapaxes(v, 1, 2),
                           jnp.full((B,), n, jnp.int32))

        @jax.jit
        def step_full(q, c, kn):
            c = dense_cache_append(c, kn, kn)
            return full_attention_decode(q, c)

        us_r = timeit(step_retro, q, state, kn)
        us_f = timeit(step_full, q, cache, kn)
        br = bytes_touched_retro(plan, retro, H, hd, m)
        bf = bytes_touched_full(n, H, hd)
        emit(f"fig13_ctx{n}_retro", us_r,
             f"kv_bytes={br};speedup_vs_full={us_f/us_r:.2f}x")
        emit(f"fig13_ctx{n}_full", us_f,
             f"kv_bytes={bf};bytes_reduction={bf/br:.1f}x")


if __name__ == "__main__":
    run()
    run_ragged_continuous()
