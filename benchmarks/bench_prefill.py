"""Paper Fig. 15: prefill latency — index construction overhead.

The paper: segmented clustering adds only 3-6% to full-attention prefill.
We time prefill with runtime="full" (no index) vs runtime="retro" (index
built via segmented k-means) on a small dense model.
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import emit, timeit, tiny_retro
from repro.configs.base import AttnConfig, InputShape, ModelConfig
from repro.configs.registry import materialize_batch
from repro.core.zones import plan_zones
from repro.models import model as M


def run():
    retro = tiny_retro(kmeans_iters=10)
    cfg = ModelConfig(
        arch_id="bench-prefill", family="dense", n_layers=4, d_model=256,
        d_ff=512, vocab=1024,
        attn=AttnConfig(n_heads=8, n_kv_heads=4, head_dim=32),
        dtype="float32", retro=retro)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    for S in (2048, 8192):
        batch = materialize_batch(cfg, InputShape("p", S, 1, "prefill"))
        plan = plan_zones(S, retro, 256)

        full_fn = jax.jit(lambda p, b: M.apply_prefill(
            p, cfg, b, runtime="full", gen_headroom=256)[0])
        retro_fn = jax.jit(lambda p, b: M.apply_prefill(
            p, cfg, b, runtime="retro", plan=plan, gen_headroom=256)[0])
        us_f = timeit(full_fn, params, batch, iters=3)
        us_r = timeit(retro_fn, params, batch, iters=3)
        emit(f"fig15_prefill{S}_full", us_f, "baseline")
        emit(f"fig15_prefill{S}_retro", us_r,
             f"index_overhead={100 * (us_r - us_f) / us_f:.1f}%")


if __name__ == "__main__":
    run()
