"""Paper Fig. 10/11 analog: end-to-end retrieval accuracy on a TRAINED model.

The paper's headline accuracy result: RetroInfer is the only sparse system
matching full attention on RULER/NIAH. At container scale we train a small
transformer on associative recall (the miniature needle task — the queried
pair sits at arbitrary depth), then evaluate recall accuracy at a LONGER
context than training under (a) full attention and (b) the wave-index
runtime at the paper's ~1.8%-style budget, plus top-1 agreement between the
two runtimes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.configs.base import AttnConfig, ModelConfig, RetroConfig
from repro.core.zones import plan_zones
from repro.data.pipeline import assoc_recall_batch
from repro.models import model as M
from repro.training.optimizer import AdamWConfig
from repro.training.train_loop import init_train_state, make_train_step

VOCAB = 128
RETRO = RetroConfig(avg_cluster=8, cluster_cap=16, prefill_segment=128,
                    update_segment=64, sink=4, local=32,
                    retrieval_frac=0.08, estimation_frac=0.3, kmeans_iters=4)

CFG = ModelConfig(
    arch_id="niah", family="dense", n_layers=2, d_model=128, d_ff=256,
    vocab=VOCAB, attn=AttnConfig(n_heads=4, n_kv_heads=4, head_dim=32),
    dtype="float32", retro=RETRO)


def _repeated_pair_stream(rng, batch, n_distinct, n_draws, vocab):
    """Streams of (k, v) tokens drawn WITH replacement from n_distinct pairs:
    values become predictable from their 2nd occurrence on — dense induction
    signal for every repeated key."""
    lo_k, hi_k = 2, vocab // 2
    lo_v, hi_v = vocab // 2, vocab
    T = 2 * n_draws
    toks = np.empty((batch, T), np.int32)
    for b in range(batch):
        keys = rng.choice(np.arange(lo_k, hi_k), size=n_distinct,
                          replace=False)
        vals = rng.integers(lo_v, hi_v, size=n_distinct)
        idx = rng.integers(0, n_distinct, size=n_draws)
        toks[b, 0::2] = keys[idx]
        toks[b, 1::2] = vals[idx]
    return toks


def train_model(steps=700, batch=64, seed=0):
    rng = np.random.default_rng(seed)
    state = init_train_state(CFG, jax.random.PRNGKey(seed))
    step_fn = jax.jit(make_train_step(
        CFG, AdamWConfig(lr=1e-2, warmup_steps=30, total_steps=steps,
                         weight_decay=0.01)))
    loss = None
    for i in range(steps):
        toks = _repeated_pair_stream(rng, batch, 6, 16, VOCAB)
        state, m = step_fn(state, {"tokens": jnp.asarray(toks[:, :-1]),
                                   "targets": jnp.asarray(toks[:, 1:])})
        loss = float(m["loss"])
    return state.params, loss


def eval_accuracy(params, runtime: str, n_pairs: int, seq: int,
                  n_eval: int = 64, seed: int = 1):
    rng = np.random.default_rng(seed)
    plan = plan_zones(seq, CFG.retro, 128)

    @jax.jit
    def prefill(params, tokens):
        return M.apply_prefill(params, CFG, {"tokens": tokens},
                               runtime=runtime, plan=plan, gen_headroom=128)

    toks, tgt = assoc_recall_batch(rng, n_eval, n_pairs, VOCAB, seq=seq)
    logits, _ = prefill(params, jnp.asarray(toks))
    pred = np.asarray(jnp.argmax(logits, -1))
    return float((pred == tgt).mean()), pred


def run():
    params, final_loss = train_model()
    emit("fig10_niah_train", 0.0, f"final_masked_loss={final_loss:.3f}")
    # evaluate at 2x the trained pair count (length generalization, 512 ctx)
    for n_pairs, seq in ((24, 256), (48, 512)):
        acc_f, pred_f = eval_accuracy(params, "full", n_pairs, seq)
        acc_r, pred_r = eval_accuracy(params, "retro", n_pairs, seq)
        agree = float((pred_f == pred_r).mean())
        emit(f"fig10_niah_pairs{n_pairs}_full", 0.0, f"acc={acc_f:.3f}")
        emit(f"fig10_niah_pairs{n_pairs}_retro", 0.0,
             f"acc={acc_r:.3f};top1_agreement_vs_full={agree:.3f}")


if __name__ == "__main__":
    run()
