"""Paper Fig. 17(b) / Table 1: long-output (reasoning) workload.

Short prompt, long generation: the index starts nearly empty and is built
incrementally by the 1K-token (here scaled-down) segment flushes — the
paper's reasoning-model setting where MagicPIG cannot run at all. Measures
decode tok/s for retro vs full and the index growth.
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import emit
from repro.configs.base import AttnConfig, ModelConfig, RetroConfig
from repro.models import model as M
from repro.serving.engine import Request, ServeEngine

RETRO = RetroConfig(avg_cluster=16, cluster_cap=32, prefill_segment=256,
                    update_segment=128, sink=4, local=64, kmeans_iters=4)

CFG = ModelConfig(
    arch_id="longgen", family="dense", n_layers=2, d_model=128, d_ff=256,
    vocab=1024, attn=AttnConfig(n_heads=4, n_kv_heads=2, head_dim=32),
    dtype="float32", retro=RETRO)


def run():
    prompt_len, new_tokens = 512, 300           # > 2 segment flushes
    params = M.init_params(CFG, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, CFG.vocab, prompt_len).astype(np.int32)
               for _ in range(2)]
    for runtime in ("retro", "full"):
        eng = ServeEngine(CFG, params, runtime=runtime, gen_headroom=512)
        reqs = [Request(prompt=p.copy(), max_new_tokens=new_tokens)
                for p in prompts]
        m = eng.run_wave(reqs)
        emit(f"fig17b_longgen_{runtime}", m.decode_s / m.tokens_out * 1e6,
             f"decode_tps={m.decode_tps:.1f};tokens={m.tokens_out}")


if __name__ == "__main__":
    run()
