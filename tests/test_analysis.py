"""retrolint test suite: every rule against its fixtures, suppression
plumbing, the CLI gate on seeded-bad trees, and the serve-level contract
regression (slow lane)."""
import os
import subprocess
import sys

import pytest

from repro.analysis import ast_rules, pallas_check
from repro.analysis.findings import (RULES, Finding, Pragmas, apply_baseline,
                                     explain_rule, load_baseline,
                                     write_baseline)
from repro.analysis.selftest import BAD_FIXTURES, FIXTURES, run_selftests
from repro.launch import lint as lint_cli

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


# ------------------------------------------------------------ rule fixtures
@pytest.mark.parametrize("fx", FIXTURES,
                         ids=[f"{f.rule}-{i}" for i, f in enumerate(FIXTURES)])
def test_rule_fixture_pair(fx):
    """Each bad fixture trips exactly its rule; its good twin stays silent."""
    bad = [f for f in fx.checker(fx.bad) if f.rule == fx.rule]
    assert bad, f"{fx.rule}: bad fixture not flagged"
    good = [f for f in fx.checker(fx.good) if f.severity == "error"]
    assert not good, f"{fx.rule}: good fixture flagged: {good[0].render()}"


def test_selftests_static_rules_pass():
    assert run_selftests(include_traced=False) == []


def test_selftests_traced_rules_pass():
    # RL101/RL102/RL103 against real traced functions (tiny jits).
    assert run_selftests(include_traced=True) == []


def test_every_rule_has_fixture_or_traced_selftest():
    fixture_rules = {fx.rule for fx in FIXTURES} | {"RL101", "RL102", "RL103"}
    # RL301-RL305 are exercised by the schedule-fixture selftests
    # (selftest._selftest_rl30x, always-on in run_selftests).
    fixture_rules |= {"RL301", "RL302", "RL303", "RL304", "RL305"}
    # RL401-RL406 are exercised by the retronum traced selftests
    # (selftest._selftest_rl40x, run under include_traced).
    fixture_rules |= {"RL401", "RL402", "RL403", "RL404", "RL405", "RL406"}
    # RL104 is advisory and exercised by the serve-level contract pass.
    assert set(RULES) - fixture_rules == {"RL104"}


# ------------------------------------------------------------------ pragmas
def test_sync_pragma_requires_reason():
    src = BAD_FIXTURES["RL001"].replace(
        "# unsanctioned host sync", "# retrolint: sync()")
    hits = [f for f in ast_rules.lint_source(src, "x.py") if f.rule == "RL001"]
    assert hits, "reasonless sync pragma must not sanction the call"


def test_sync_pragma_with_reason_sanctions():
    src = BAD_FIXTURES["RL001"].replace(
        "# unsanctioned host sync", "# retrolint: sync(test readback)")
    assert not [f for f in ast_rules.lint_source(src, "x.py")
                if f.rule == "RL001"]


def test_ignore_pragma_names_the_rule():
    src = BAD_FIXTURES["RL002"].replace(
        "# traced-value branch", "# retrolint: ignore(RL002: trace-checked)")
    assert not [f for f in ast_rules.lint_source(src, "x.py")
                if f.rule == "RL002"]
    # an ignore for a DIFFERENT rule must not suppress it
    src = BAD_FIXTURES["RL002"].replace(
        "# traced-value branch", "# retrolint: ignore(RL003: wrong rule)")
    assert [f for f in ast_rules.lint_source(src, "x.py")
            if f.rule == "RL002"]


def test_hot_pragma_extends_hot_set():
    src = BAD_FIXTURES["RL001"].replace("  # retrolint: hot", "")
    assert not [f for f in ast_rules.lint_source(src, "x.py")
                if f.rule == "RL001"], "without the hot mark, syncs are fine"


def test_pragma_scan_multiline_call():
    p = Pragmas.scan("x = f(  # retrolint: sync(reason)\n    y)\n")
    assert p.sanctions_sync(1) and not p.sanctions_sync(2)


# ----------------------------------------------------------------- baseline
def test_baseline_roundtrip(tmp_path):
    f1 = Finding("RL001", "src/a.py", 10, "f", "sync np.asarray")
    f2 = Finding("RL203", "src/k.py", 3, "g", "footprint 99 bytes")
    adv = Finding("RL104", "src/e.py", 0, "s", "arg 1 copy", severity="advice")
    path = str(tmp_path / "baseline.txt")
    write_baseline(path, [f1, f2, adv])
    base = load_baseline(path)
    assert {f1.fingerprint, f2.fingerprint} == base   # advice never baselined
    visible = apply_baseline([f1, f2, adv], base)
    assert visible == [adv]                           # advice passes through


def test_fingerprint_survives_line_and_count_edits():
    a = Finding("RL203", "src/k.py", 3, "g", "footprint 99 bytes")
    b = Finding("RL203", "src/k.py", 77, "g", "footprint 1024 bytes")
    assert a.fingerprint == b.fingerprint
    c = Finding("RL203", "src/other.py", 3, "g", "footprint 99 bytes")
    assert a.fingerprint != c.fingerprint


def test_load_baseline_missing_file_is_empty(tmp_path):
    assert load_baseline(str(tmp_path / "nope.txt")) == set()


# ------------------------------------------------------------------ explain
def test_explain_covers_every_rule():
    for rid, rule in RULES.items():
        text = explain_rule(rid)
        assert text and rid in text and rule.title in text
    assert explain_rule("RL999") is None


def test_cli_explain_exit_codes(capsys):
    assert lint_cli.main(["--explain", "rl001"]) == 0
    assert "hot" in capsys.readouterr().out
    assert lint_cli.main(["--explain", "RL999"]) == 2


# ----------------------------------------------------------------- CLI gate
def _seed_tree(tmp_path, files):
    for rel, src in files.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(src)
    return str(tmp_path)


def test_cli_clean_tree_exits_zero(tmp_path):
    root = _seed_tree(tmp_path, {
        "src/repro/__init__.py": "",
        "src/repro/clean.py": "import jax\n\ndef f(x):\n    return x\n"})
    assert lint_cli.main(["--root", root, "--no-trace", "-q"]) == 0


@pytest.mark.parametrize("rule", sorted(BAD_FIXTURES))
def test_cli_seeded_bad_fixture_trips_gate(tmp_path, rule, capsys):
    # Pallas rules only run under src/repro/kernels; AST rules anywhere in src
    rel = ("src/repro/kernels/bad.py" if rule.startswith("RL2")
           else "src/repro/bad.py")
    root = _seed_tree(tmp_path, {rel: BAD_FIXTURES[rule]})
    assert lint_cli.main(["--root", root, "--no-trace", "-q"]) == 1
    assert rule in capsys.readouterr().out


def test_cli_write_baseline_then_clean(tmp_path):
    root = _seed_tree(tmp_path, {"src/repro/bad.py": BAD_FIXTURES["RL003"]})
    assert lint_cli.main(["--root", root, "--no-trace", "-q",
                          "--write-baseline"]) == 0
    # the freshly written baseline suppresses the seeded finding
    assert lint_cli.main(["--root", root, "--no-trace", "-q"]) == 0


def test_cli_bad_geometry_exits_two(tmp_path):
    with pytest.raises(SystemExit):
        lint_cli.main(["--root", str(tmp_path), "--geometry", "oops"])


# --------------------------------------------------------- repo is the proof
def test_repo_static_passes_are_clean():
    """The checked-in tree is the canonical good fixture: zero static
    errors with the checked-in (empty) baseline."""
    findings = ast_rules.lint_tree(REPO) + pallas_check.check_tree(REPO)
    visible = apply_baseline(
        findings, load_baseline(os.path.join(REPO, "lint_baseline.txt")))
    errors = [f.render() for f in visible if f.severity == "error"]
    assert not errors, "\n".join(errors)


def test_engine_sanctioned_syncs_are_all_annotated():
    """Every np.asarray-style sync in the serve hot path carries a reasoned
    pragma — the sync inventory the kernel README documents."""
    path = os.path.join(REPO, "src", "repro", "serving", "engine.py")
    with open(path) as f:
        src = f.read()
    pragmas = Pragmas.scan(src)
    reasons = [payload for entries in pragmas.by_line.values()
               for kind, payload in entries if kind == "sync"]
    assert len(reasons) >= 7 and all(reasons), reasons


def test_serve_stage_contract_shape():
    from repro.serving.engine import SERVE_STAGES
    assert SERVE_STAGES["rank_fn"]["donate"] == (2,)
    assert SERVE_STAGES["offload_flush"]["donate"] == (0,)
    assert SERVE_STAGES["cache_upd"]["donate"] == (0, 1, 2)
    for name, contract in SERVE_STAGES.items():
        assert contract["budget"] in ("per_geometry", "per_prompt_len",
                                      "per_prompt_bucket", "host"), name
        # retrosched contract: every stage declares its buffer effects
        eff = contract["effects"]
        assert set(eff) <= {"reads", "writes", "donates", "passes"}, name
        for slot in eff.values():
            assert isinstance(slot, tuple), name
        # host control-plane steps never run on the stream
        if contract["budget"] == "host":
            assert contract["space"] == "host", name


# ------------------------------------------------------------- retrosched
def test_schedule_pipelined_reference_is_clean():
    from repro.analysis.schedule_check import (check_trace,
                                               reference_schedule)
    from repro.analysis.schedule_model import build_trace
    tr = build_trace(reference_schedule(pipelined=True), n_layers=2)
    assert check_trace(tr) == []
    warm = build_trace(reference_schedule(pipelined=True, warm=True),
                       n_layers=2)
    assert check_trace(warm) == []


def test_schedule_prepipeline_order_advises_rl304():
    from repro.analysis.schedule_check import (check_trace,
                                               reference_schedule)
    from repro.analysis.schedule_model import build_trace
    tr = build_trace(reference_schedule(pipelined=False), n_layers=2)
    found = check_trace(tr)
    assert [f.rule for f in found] == ["RL304"]
    assert found[0].severity == "advice"       # never gates


def test_schedule_dropped_mirror_errors_rl302():
    from repro.analysis.schedule_check import (check_trace,
                                               reference_schedule)
    from repro.analysis.schedule_model import build_trace
    tr = build_trace(reference_schedule(drop_mirror=True), n_layers=2)
    assert "RL302" in {f.rule for f in check_trace(tr)}


def test_schedule_empty_trace_is_an_error():
    from repro.analysis.schedule_check import schedule_findings
    found = schedule_findings(None)
    assert len(found) == 1 and found[0].rule == "RL301"
    assert found[0].severity == "error"


def test_cli_json_output(tmp_path, capsys):
    import json as _json
    root = _seed_tree(tmp_path, {
        "src/repro/__init__.py": "",
        "src/repro/bad.py": BAD_FIXTURES["RL003"]})
    assert lint_cli.main(["--root", root, "--no-trace", "-q",
                          "--json"]) == 1
    doc = _json.loads(capsys.readouterr().out)
    assert doc["errors"] >= 1 and doc["ok"] is False
    f = doc["findings"][0]
    assert {"rule", "path", "line", "qualname", "message", "severity",
            "fingerprint"} <= set(f)


def test_cli_github_annotations(tmp_path, capsys):
    root = _seed_tree(tmp_path, {
        "src/repro/__init__.py": "",
        "src/repro/bad.py": BAD_FIXTURES["RL003"]})
    assert lint_cli.main(["--root", root, "--no-trace", "-q",
                          "--github"]) == 1
    out = capsys.readouterr().out
    assert "::error file=" in out and "title=retrolint RL003" in out


def test_selftest_cli_entrypoint():
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.lint", "--selftest"],
        capture_output=True, text=True, cwd=REPO,
        env={**os.environ, "PYTHONPATH": os.path.join(REPO, "src"),
             "JAX_PLATFORMS": "cpu"})
    assert out.returncode == 0, out.stdout + out.stderr
    assert "ok (0 failures)" in out.stdout


# -------------------------------------------------- serve-level regression
@pytest.mark.slow
def test_serve_contract_checks_hold():
    """Trace-time gate over two real mixed serve runs: zero unsanctioned
    callbacks, every contracted donation truly aliases (including the
    rank_fn/offload_flush donations this contract flagged as missing), and
    every stage compiles exactly its budget."""
    from repro.analysis.jaxpr_check import run_contract_checks
    findings = run_contract_checks()
    errors = [f.render() for f in findings if f.severity == "error"]
    assert not errors, "\n".join(errors)
    assert not findings, [f.render() for f in findings]  # no advice either


# --------------------------------------------------- retronum (RL401-406)
def test_repo_numerics_pass_is_clean():
    """The curated bf16 decode traces (dense fallback, both zone walks, the
    paged kernel, the LSE-merge path) carry zero precision-contract errors
    — and the RL406 VMEM cast-site inventory is non-empty (the quantization
    roadmap item hooks dequant into exactly these sites)."""
    from repro.analysis.numerics_check import run_numerics_checks
    findings = run_numerics_checks()
    errors = [f.render() for f in findings if f.severity == "error"]
    assert not errors, "\n".join(errors)
    inventory = [f for f in findings if f.rule == "RL406"]
    assert inventory, "paged-kernel cast-site inventory is empty"
    assert all(f.severity == "advice" for f in inventory)
    assert all("kernel.py" in f.path for f in inventory), \
        [f.path for f in inventory]


def test_numerics_catches_dense_cache_upcast():
    """The exact bug the RL402 dense-path fix removed: whole-cache astype
    upcasts before the einsums must trip the hoisted-cast rule."""
    import jax
    import jax.numpy as jnp
    import math as pymath
    from repro.analysis.numerics_check import numerics_findings
    from repro.core.attention import DenseCache

    def old_dense_decode(q, cache):                 # pre-PR-10 body
        B, Hq, hd = q.shape
        Hkv = cache.k.shape[1]
        qg = q.reshape(B, Hkv, Hq // Hkv, hd)
        s = jnp.einsum("bhgd,bhtd->bhgt", qg.astype(jnp.float32),
                       cache.k.astype(jnp.float32)) / pymath.sqrt(hd)
        p = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bhgt,bhtd->bhgd", p, cache.v.astype(jnp.float32))
        return out.reshape(B, Hq, hd).astype(q.dtype)

    B, H, S, hd = 2, 4, 8192, 128
    a = jax.ShapeDtypeStruct
    cache = DenseCache(a((B, H, S, hd), jnp.bfloat16),
                       a((B, H, S, hd), jnp.bfloat16), a((B,), jnp.int32))
    fs = numerics_findings(old_dense_decode,
                           (a((B, 2 * H, hd), jnp.bfloat16), cache),
                           "old_dense_decode", path="x.py")
    assert sum(f.rule == "RL402" for f in fs) >= 2, \
        [f.render() for f in fs]


def test_serve_stage_numerics_contracts():
    """Every device stage declares the numerics contract (schema-checked);
    host control-plane steps carry none."""
    from repro.analysis.numerics_check import NumericsContract
    from repro.serving.engine import SERVE_STAGES
    for name, contract in SERVE_STAGES.items():
        if contract["space"] == "device":
            spec = contract.get("numerics")
            assert spec is not None, f"{name}: device stage without numerics"
            nc = NumericsContract.from_spec(spec)   # raises on bad keys
            assert nc.narrow in ("output-only", "free"), name
        else:
            assert contract.get("numerics") is None, name


def test_numerics_findings_surface_in_lint_json(tmp_path):
    """--json-out writes the same JSON document the gate prints, so CI can
    upload the RL406 inventory from the single gate run."""
    import json as pyjson
    root = _seed_tree(tmp_path, {
        "src/repro/__init__.py": "",
        "src/repro/ok.py": "x = 1\n"})
    out_path = str(tmp_path / "inv.json")
    assert lint_cli.main(["--root", root, "--no-trace", "-q", "--json",
                          "--json-out", out_path]) == 0
    with open(out_path) as fh:
        doc = pyjson.load(fh)
    assert doc["ok"] and doc["findings"] == []
