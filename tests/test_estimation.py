"""Property tests for the paper's accuracy-bounded attention estimation.

Runs with or without ``hypothesis``: when it is installed the property tests
explore generated inputs; on a clean environment they fall back to seeded
numpy sweeps over the same checks, so ``pytest`` always collects cleanly.
"""
import jax.numpy as jnp
import numpy as np
import pytest

try:
    import hypothesis.extra.numpy as hnp
    import hypothesis.strategies as st
    from hypothesis import given, settings
    HAVE_HYPOTHESIS = True
except ImportError:                                       # pragma: no cover
    HAVE_HYPOTHESIS = False

from repro.configs.base import RetroConfig
from repro.core.attention import (DenseCache, full_attention_decode,
                                  wave_attention_decode)
from repro.core.wave_index import max_clusters, prefill_build
from repro.core.zones import plan_zones

RETRO = RetroConfig(avg_cluster=8, cluster_cap=16, prefill_segment=256,
                    update_segment=128, sink=4, local=32, kmeans_iters=3)
# capacity = segment size => provably no store overflow (exactness tests)
RETRO_EXACT = RetroConfig(avg_cluster=8, cluster_cap=256, prefill_segment=256,
                          update_segment=128, sink=4, local=32, kmeans_iters=3)


def _check_jensen(q, keys):
    """exp(q·centroid) <= mean(exp(q·k)) — Eq. 3 of the paper."""
    c = keys.mean(axis=0)
    lhs = np.exp(np.dot(q, c))
    rhs = np.mean(np.exp(keys @ q))
    assert lhs <= rhs * (1 + 1e-4) + 1e-6


def _check_denominator_lower_bound(seed):
    """The estimated softmax denominator never exceeds the true one (per-head),
    so estimated attention weights are never inflated."""
    rng = np.random.default_rng(seed)
    n, hd = 512, 32
    keys = rng.standard_normal((1, n, 1, hd)).astype(np.float32)
    vals = rng.standard_normal((1, n, 1, hd)).astype(np.float32)
    q = rng.standard_normal((hd,)).astype(np.float32)
    M = max_clusters(n, RETRO, gen_headroom=128)
    state = prefill_build(jnp.asarray(keys), jnp.asarray(vals), RETRO, M,
                          dtype=jnp.float32)
    # true denominator over clustered region
    cl = np.asarray(state.size[0, 0])
    active = int(state.n_clusters[0])
    scores = (keys[0, :, 0] @ q) / np.sqrt(hd)
    # estimated per-cluster mass s_i * exp(q.c_i) vs true sum of exp within
    cent = np.asarray(state.centroid[0, 0][:active])
    est = cl[:active] * np.exp(cent @ q / np.sqrt(hd))
    pos = np.asarray(state.pos_store[0, 0][:active])            # (m, cap)
    true = np.zeros(active)
    for i in range(active):
        p = pos[i][pos[i] >= 0]
        # include overflowed members via size bookkeeping: stored only
        true[i] = np.exp(scores[p]).sum()
    stored = np.asarray(state.stored[0, 0][:active])
    full_cluster = stored == cl[:active]
    assert np.all(est[full_cluster] <= true[full_cluster] * (1 + 1e-4) + 1e-6)


if HAVE_HYPOTHESIS:
    @settings(max_examples=50, deadline=None)
    @given(
        q=hnp.arrays(np.float32, (16,), elements=st.floats(-3, 3, width=32)),
        keys=hnp.arrays(np.float32, (24, 16),
                        elements=st.floats(-3, 3, width=32)),
    )
    def test_jensen_lower_bound(q, keys):
        _check_jensen(q, keys)

    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 2**16))
    def test_estimation_denominator_is_lower_bound(seed):
        _check_denominator_lower_bound(seed)
else:
    @pytest.mark.parametrize("seed", range(25))
    def test_jensen_lower_bound(seed):
        rng = np.random.default_rng(seed)
        q = rng.uniform(-3.0, 3.0, 16).astype(np.float32)
        keys = rng.uniform(-3.0, 3.0, (24, 16)).astype(np.float32)
        _check_jensen(q, keys)

    @pytest.mark.parametrize("seed", (0, 7, 101, 4096))
    def test_estimation_denominator_is_lower_bound(seed):
        _check_denominator_lower_bound(seed)


def _mk_state(seed=0, n=1100, hd=32, B=2, H=2, retro=RETRO):
    rng = np.random.default_rng(seed)
    k = jnp.asarray(rng.standard_normal((B, n, H, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, n, H, hd)), jnp.float32)
    M = max_clusters(n, retro, gen_headroom=128)
    state = prefill_build(k, v, retro, M, dtype=jnp.float32)
    cache = DenseCache(jnp.swapaxes(k, 1, 2), jnp.swapaxes(v, 1, 2),
                       jnp.full((B,), n, jnp.int32))
    q = jnp.asarray(rng.standard_normal((B, 2 * H, hd)), jnp.float32)
    return q, state, cache, n


def test_exactness_full_retrieval():
    """r = all clusters, estimation off => identical to full attention."""
    q, state, cache, n = _mk_state(retro=RETRO_EXACT)
    plan = plan_zones(n, RETRO_EXACT, 128)._replace(e=0)
    plan = plan._replace(r=int(state.n_clusters[0]))
    out = wave_attention_decode(q, state, RETRO_EXACT, plan,
                                use_estimation=False,
                                overflow_correction=False)
    ref = full_attention_decode(q, cache)
    np.testing.assert_allclose(np.asarray(out.out), np.asarray(ref),
                               atol=2e-5, rtol=1e-4)


def test_estimation_reduces_error():
    """Estimation zone strictly improves output fidelity at small budgets
    (paper Fig. 19a)."""
    q, state, cache, n = _mk_state(seed=3)
    ref = np.asarray(full_attention_decode(q, cache))
    plan = plan_zones(n, RETRO, 128)._replace(r=2)
    with_est = wave_attention_decode(q, state, RETRO, plan).out
    no_est = wave_attention_decode(q, state, RETRO, plan,
                                   use_estimation=False).out
    e1 = np.abs(np.asarray(with_est) - ref).max()
    e0 = np.abs(np.asarray(no_est) - ref).max()
    assert e1 < e0


def test_error_monotone_in_budget():
    """More retrieval budget => closer to full attention (on average)."""
    q, state, cache, n = _mk_state(seed=7)
    ref = np.asarray(full_attention_decode(q, cache))
    errs = []
    for r in (1, 8, 32, int(state.n_clusters[0])):
        plan = plan_zones(n, RETRO, 128)._replace(r=r, e=0)
        out = wave_attention_decode(q, state, RETRO, plan,
                                    use_estimation=False,
                                    overflow_correction=False).out
        errs.append(float(np.abs(np.asarray(out) - ref).mean()))
    assert errs[-1] < errs[0]
    assert errs[-1] <= 1e-5
    assert errs[2] <= errs[0] * 1.05


def test_softcap_consistency():
    """Softcapped wave attention with full retrieval matches softcapped full
    attention (gemma2 path)."""
    q, state, cache, n = _mk_state(seed=11, retro=RETRO_EXACT)
    plan = plan_zones(n, RETRO_EXACT, 128)._replace(e=0)
    plan = plan._replace(r=int(state.n_clusters[0]))
    out = wave_attention_decode(q, state, RETRO_EXACT, plan, softcap=30.0,
                                use_estimation=False,
                                overflow_correction=False)
    ref = full_attention_decode(q, cache, softcap=30.0)
    np.testing.assert_allclose(np.asarray(out.out), np.asarray(ref),
                               atol=2e-5, rtol=1e-4)


def test_sliding_window_consistency():
    """Windowed wave attention (cluster-level window masking) matches windowed
    full attention when retrieval covers everything."""
    q, state, cache, n = _mk_state(seed=13, retro=RETRO_EXACT)
    plan = plan_zones(n, RETRO_EXACT, 128)._replace(e=0)
    plan = plan._replace(r=int(state.n_clusters[0]))
    w = jnp.asarray(300.0)
    out = wave_attention_decode(q, state, RETRO_EXACT, plan, window=w,
                                use_estimation=False,
                                overflow_correction=False)
    ref = full_attention_decode(q, cache, window=w)
    np.testing.assert_allclose(np.asarray(out.out), np.asarray(ref),
                               atol=2e-5, rtol=1e-4)
