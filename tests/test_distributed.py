"""Distributed wave attention (shard_map local retrieval + LSE psum)."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import RetroConfig
from repro.core.attention import wave_attention_decode
from repro.core.distributed import distributed_wave_attention, local_plan
from repro.core.wave_index import max_clusters, prefill_build
from repro.core.zones import plan_zones

RETRO = RetroConfig(avg_cluster=8, cluster_cap=16, prefill_segment=256,
                    update_segment=128, sink=4, local=32, kmeans_iters=3)


def test_single_shard_equals_serial():
    """On a 1-device 'model' mesh the distributed path must equal the serial
    path bit-for-bit (local top-r == global top-r)."""
    rng = np.random.default_rng(0)
    B, n, H, hd = 2, 1100, 2, 32
    k = jnp.asarray(rng.standard_normal((B, n, H, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, n, H, hd)), jnp.float32)
    state = prefill_build(k, v, RETRO, max_clusters(n, RETRO, 128),
                          dtype=jnp.float32)
    q = jnp.asarray(rng.standard_normal((B, 2 * H, hd)), jnp.float32)
    plan = plan_zones(n, RETRO, 128)
    mesh = jax.make_mesh((1,), ("model",))
    serial = wave_attention_decode(q, state, RETRO, plan).out
    dist = distributed_wave_attention(q, state, RETRO, plan, mesh)
    np.testing.assert_allclose(np.asarray(serial), np.asarray(dist),
                               atol=1e-5, rtol=1e-5)


def test_local_plan_ceil():
    plan = plan_zones(1100, RETRO, 128)._replace(r=10, e=33)
    lp = local_plan(plan, 4)
    assert lp.r == 3 and lp.e == 9


@pytest.mark.slow
def test_multi_shard_exact_when_full_coverage():
    """8 fake devices: with r covering all clusters per shard, the distributed
    result equals full-coverage serial attention exactly (subprocess)."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import RetroConfig
from repro.core.attention import wave_attention_decode
from repro.core.distributed import distributed_wave_attention
from repro.core.wave_index import max_clusters, prefill_build
from repro.core.zones import plan_zones

RETRO = RetroConfig(avg_cluster=8, cluster_cap=256, prefill_segment=256,
                    update_segment=128, sink=4, local=32, kmeans_iters=3)
rng = np.random.default_rng(0)
B, n, H, hd = 2, 2084, 2, 32
k = jnp.asarray(rng.standard_normal((B, n, H, hd)), jnp.float32)
v = jnp.asarray(rng.standard_normal((B, n, H, hd)), jnp.float32)
M = max_clusters(n, RETRO, 128)          # padded to 256-multiple: 8 | M
state = prefill_build(k, v, RETRO, M, dtype=jnp.float32)
q = jnp.asarray(rng.standard_normal((B, 2 * H, hd)), jnp.float32)
plan = plan_zones(n, RETRO, 128)._replace(r=M, e=0)
mesh = jax.make_mesh((4,), ("model",))
serial = wave_attention_decode(q, state, RETRO, plan).out
dist = distributed_wave_attention(q, state, RETRO, plan, mesh)
err = float(jnp.max(jnp.abs(serial - dist)))
print("ERR", err)
assert err < 1e-4, err

# budgeted, structured keys: local-union retrieval must be about as close
# to FULL attention as global top-r retrieval is
from repro.core.attention import DenseCache, full_attention_decode
from repro.data.pipeline import clustered_keys
keys, qv, hot = clustered_keys(n, hd, n_hot=6, seed=1)
vals = rng.standard_normal((n, hd)).astype(np.float32)
k2 = jnp.asarray(keys)[None, :, None, :].repeat(B, 0).repeat(H, 2)
v2 = jnp.asarray(vals)[None, :, None, :].repeat(B, 0).repeat(H, 2)
st2 = prefill_build(k2, v2, RETRO, M, dtype=jnp.float32)
q2 = jnp.asarray(qv)[None, None, :].repeat(B, 0).repeat(2 * H, 1)
cache = DenseCache(jnp.swapaxes(k2, 1, 2), jnp.swapaxes(v2, 1, 2),
                   jnp.full((k2.shape[0],), n, jnp.int32))
ref = full_attention_decode(q2, cache)
plan_b = plan_zones(n, RETRO, 128)
e_ser = float(jnp.linalg.norm(
    wave_attention_decode(q2, st2, RETRO, plan_b).out - ref))
e_dist = float(jnp.linalg.norm(
    distributed_wave_attention(q2, st2, RETRO, plan_b, mesh) - ref))
print("E_SER", e_ser, "E_DIST", e_dist)
assert e_dist <= 2.0 * e_ser + 1e-3, (e_ser, e_dist)
print("DIST_OK")
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("JAX_PLATFORMS", None)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=900, env=env)
    assert "DIST_OK" in out.stdout, (out.stdout[-1000:], out.stderr[-3000:])
