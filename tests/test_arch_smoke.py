"""Deliverable (f): per-architecture smoke tests — a REDUCED variant of each
assigned arch family runs one forward/train step + prefill/decode on CPU,
asserting output shapes and finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import InputShape
from repro.configs.registry import (ARCH_IDS, materialize_batch,
                                    reduced_config)
from repro.core.zones import plan_zones
from repro.models import model as M
from repro.training.optimizer import AdamWConfig
from repro.training.train_loop import init_train_state, make_train_step

S, B = 384, 2
TRAIN = InputShape("t", 256, B, "train")
PRE = InputShape("p", S, B, "prefill")


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step(arch):
    cfg = reduced_config(arch)
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    batch = materialize_batch(cfg, TRAIN)
    step = jax.jit(make_train_step(cfg, AdamWConfig(warmup_steps=2,
                                                    total_steps=10)))
    state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params updated and finite
    leaf = jax.tree.leaves(state.params)[0]
    assert np.isfinite(np.asarray(leaf)).all()


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("runtime", ["retro", "full"])
def test_prefill_decode(arch, runtime):
    cfg = reduced_config(arch)
    if cfg.family == "ssm" and runtime == "full":
        pytest.skip("attention-free: single recurrent runtime")
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    batch = materialize_batch(cfg, PRE)
    plan = plan_zones(S, cfg.retro, 256) if cfg.family != "ssm" else None
    logits, state = M.apply_prefill(params, cfg, batch, runtime=runtime,
                                    plan=plan, gen_headroom=256)
    assert logits.shape == (B, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    for _ in range(3):
        logits, state = M.apply_decode(params, cfg, state, tok,
                                       runtime=runtime, plan=plan, seq_len=S,
                                       gen_headroom=256)
        assert logits.shape == (B, cfg.vocab)
        assert np.isfinite(np.asarray(logits)).all()
        tok = jnp.argmax(logits, -1).astype(jnp.int32)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_serve_state_specs_match(arch):
    """Dry-run state stand-ins structurally match real prefill output."""
    cfg = reduced_config(arch)
    specs = M.serve_state_specs(cfg, B, S, runtime="retro", gen_headroom=256)
    params = M.init_params(cfg, jax.random.PRNGKey(2))
    batch = materialize_batch(cfg, PRE)
    _, state = M.apply_prefill(params, cfg, batch, runtime="retro",
                               gen_headroom=256)
    spec_td = jax.tree.structure(specs)
    real_td = jax.tree.structure(state)
    assert spec_td == real_td
    for s_leaf, r_leaf in zip(jax.tree.leaves(specs), jax.tree.leaves(state)):
        assert s_leaf.shape == r_leaf.shape, (arch, s_leaf.shape, r_leaf.shape)
        assert s_leaf.dtype == r_leaf.dtype
