"""End-to-end system behaviour: the serving engine with the wave index vs the
full-attention baseline, flush equivalence, and engine waves."""
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import AttnConfig, InputShape, ModelConfig, RetroConfig
from repro.configs.registry import materialize_batch
from repro.core.zones import plan_zones
from repro.models import model as M
from repro.serving.engine import Request, ServeEngine

# capacity = prefill segment => provably overflow-free exact coverage
RETRO_X = RetroConfig(avg_cluster=8, cluster_cap=64, prefill_segment=64,
                      update_segment=32, sink=4, local=32,
                      retrieval_frac=1.0, estimation_frac=0.0, kmeans_iters=3)

CFG = ModelConfig(
    arch_id="sys-tiny", family="dense", n_layers=2, d_model=64, d_ff=128,
    vocab=256, attn=AttnConfig(n_heads=4, n_kv_heads=2, head_dim=16),
    dtype="float32", retro=RETRO_X)

S, B = 384, 2


@pytest.fixture(scope="module")
def setup():
    params = M.init_params(CFG, jax.random.PRNGKey(0))
    batch = materialize_batch(CFG, InputShape("p", S, B, "prefill"))
    plan = plan_zones(S, CFG.retro, 256)

    @partial(jax.jit, static_argnames=("runtime", "inline_flush"))
    def decode(params, state, token, runtime="retro", inline_flush=False):
        return M.apply_decode(params, CFG, state, token, runtime=runtime,
                              plan=plan, inline_flush=inline_flush)

    @jax.jit
    def flush(state):
        return M.flush_state(CFG, state, runtime="retro")

    return params, batch, plan, decode, flush


def test_retro_full_budget_matches_full_attention(setup):
    """With retrieval covering all clusters the wave-index runtime reproduces
    the dense-cache runtime's logits on a real model end-to-end."""
    params, batch, plan, decode, _ = setup
    lg_r, st_r = M.apply_prefill(params, CFG, batch, runtime="retro",
                                 plan=plan, gen_headroom=256)
    lg_f, st_f = M.apply_prefill(params, CFG, batch, runtime="full",
                                 gen_headroom=256)
    np.testing.assert_allclose(np.asarray(lg_r), np.asarray(lg_f), atol=1e-3,
                               rtol=1e-3)
    tok = jnp.argmax(lg_r, -1).astype(jnp.int32)
    for _ in range(5):
        lg_r, st_r = decode(params, st_r, tok, runtime="retro")
        lg_f, st_f = decode(params, st_f, tok, runtime="full")
        np.testing.assert_allclose(np.asarray(lg_r), np.asarray(lg_f),
                                   atol=2e-3, rtol=2e-3)
        t_r = np.argmax(np.asarray(lg_r), -1)
        t_f = np.argmax(np.asarray(lg_f), -1)
        np.testing.assert_array_equal(t_r, t_f)
        tok = jnp.asarray(t_r, jnp.int32)


def test_engine_flush_matches_inline_flush(setup):
    """External (engine-driven) index updates == inline (in-step) updates."""
    params, batch, plan, decode, flush = setup
    n_steps = CFG.retro.update_segment + 4

    _, st_a = M.apply_prefill(params, CFG, batch, runtime="retro", plan=plan,
                              gen_headroom=256)
    _, st_b = M.apply_prefill(params, CFG, batch, runtime="retro", plan=plan,
                              gen_headroom=256)
    tok_a = tok_b = jnp.zeros((B,), jnp.int32)
    appended = 0
    for i in range(n_steps):
        lg_a, st_a = decode(params, st_a, tok_a, inline_flush=True)
        lg_b, st_b = decode(params, st_b, tok_b, inline_flush=False)
        appended += 1
        if M.needs_flush(CFG, appended):
            st_b = flush(st_b)
            appended = 0
        np.testing.assert_allclose(np.asarray(lg_a), np.asarray(lg_b),
                                   atol=1e-4, rtol=1e-4)
        tok_a = jnp.argmax(lg_a, -1).astype(jnp.int32)
        tok_b = jnp.argmax(lg_b, -1).astype(jnp.int32)
    assert int(st_b.kv.n_clusters[0, 0]) == int(st_a.kv.n_clusters[0, 0])


def test_engine_continuous_queue(setup):
    """A queue longer than the slot count drains through continuous batching;
    only real sampled tokens are counted (no padding inflation)."""
    params = setup[0]
    eng = ServeEngine(CFG, params, runtime="retro", gen_headroom=256)
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(0, CFG.vocab, S).astype(np.int32),
                    max_new_tokens=6) for _ in range(3)]
    m = eng.serve(reqs, batch_size=2)
    for r in reqs:
        assert len(r.out_tokens) == 6
        assert r.done
    assert m.tokens_out == 3 * 6            # odd queue: no padding slot counted
    assert m.decode_tps > 0
    assert m.n_slots == 2
    assert 0 < m.slot_occupancy <= 1
    assert len(m.ttft_s) == 3 and len(m.request_tps) == 3


@pytest.mark.slow
def test_continuous_matches_solo_bitwise(setup):
    """Acceptance: a mixed queue of >= 3 distinct prompt lengths with
    staggered max_new_tokens; every request's greedy output is bit-identical
    to running it alone at batch size 1 (same engine geometry)."""
    params = setup[0]
    rng = np.random.default_rng(7)
    lens = [S, 256, 320, 200]               # 4 distinct lengths, ragged
    news = [20, 6, 41, 12]                  # staggered; 41 crosses a flush
    prompts = [rng.integers(0, CFG.vocab, L).astype(np.int32) for L in lens]

    eng = ServeEngine(CFG, params, runtime="retro", gen_headroom=256,
                      max_context=S)
    reqs = [Request(prompt=p.copy(), max_new_tokens=n)
            for p, n in zip(prompts, news)]
    m = eng.serve(reqs, batch_size=2)
    assert m.tokens_out == sum(news)

    solo = ServeEngine(CFG, params, runtime="retro", gen_headroom=256,
                       max_context=S)
    for p, n, served in zip(prompts, news, reqs):
        ref = Request(prompt=p.copy(), max_new_tokens=n)
        solo.serve([ref], batch_size=1)
        assert ref.out_tokens == served.out_tokens, len(p)
        assert len(served.out_tokens) == n


@pytest.mark.slow
def test_chunked_admission_matches_blocking(setup):
    """Acceptance: chunked (interleaved) admission reproduces blocking
    admission token-for-token on a ragged queue, for both runtimes."""
    params = setup[0]
    rng = np.random.default_rng(3)
    lens = [S, 256, 320, 200]
    news = [20, 6, 41, 12]                  # 41 crosses a flush boundary
    prompts = [rng.integers(0, CFG.vocab, L).astype(np.int32) for L in lens]

    for runtime in ("retro", "full"):
        outs = {}
        for mode in ("blocking", "chunked"):
            eng = ServeEngine(CFG, params, runtime=runtime, gen_headroom=256,
                              max_context=S, admission=mode, prefill_chunk=96)
            reqs = [Request(prompt=p.copy(), max_new_tokens=n)
                    for p, n in zip(prompts, news)]
            m = eng.serve(reqs, batch_size=2)
            assert m.tokens_out == sum(news)
            outs[mode] = [r.out_tokens for r in reqs]
        assert outs["chunked"] == outs["blocking"], runtime


@pytest.mark.slow
def test_fused_attn_impl_matches_jnp(setup):
    """Acceptance: the gather-free fused decode attention reproduces the jnp
    reference token-for-token through the serving engine (ragged queue,
    continuous batching, flush boundaries)."""
    params = setup[0]
    rng = np.random.default_rng(11)
    lens = [S, 256, 320, 200]
    news = [20, 6, 41, 12]                  # 41 crosses a flush boundary
    prompts = [rng.integers(0, CFG.vocab, L).astype(np.int32) for L in lens]

    outs = {}
    for impl in ("jnp", "fused"):
        eng = ServeEngine(CFG, params, runtime="retro", gen_headroom=256,
                          max_context=S, attn_impl=impl)
        assert eng.attn_impl == impl
        reqs = [Request(prompt=p.copy(), max_new_tokens=n)
                for p, n in zip(prompts, news)]
        m = eng.serve(reqs, batch_size=2)
        assert m.tokens_out == sum(news)
        outs[impl] = [r.out_tokens for r in reqs]
    assert outs["fused"] == outs["jnp"]


def test_attn_impl_config_default_and_validation(setup):
    """attn_impl plumbs from RetroConfig through the engine; unknown values
    are rejected up front."""
    import dataclasses
    params = setup[0]
    cfg_f = CFG.replace(retro=dataclasses.replace(RETRO_X, attn_impl="fused"))
    eng = ServeEngine(cfg_f, params, runtime="retro", gen_headroom=256)
    assert eng.attn_impl == "fused"
    with pytest.raises(ValueError, match="attn impl"):
        ServeEngine(CFG, params, attn_impl="nope")


def test_dense_cache_append_active_mask_is_o1():
    """§Perf: the active-masked dense-cache append must not materialize a
    full-cache copy — the mask applies to the appended token, so the donated
    cache updates in place and bytes-accessed stays within a whisker of the
    unmasked append (it used to be ~2x cache size)."""
    from functools import partial

    from conftest import cost_bytes
    from repro.core.attention import dense_cache_append, init_dense_cache

    B, H, S_max, hd = 2, 2, 4096, 64
    cache = init_dense_cache(B, H, S_max, hd, dtype=jnp.float32)
    k_new = jnp.ones((B, H, hd), jnp.float32)
    act = jnp.asarray([True, False])

    def bytes_of(fn, *args):
        return cost_bytes(fn.lower(*args).compile())

    plain = partial(jax.jit, donate_argnums=(0,))
    b_nomask = bytes_of(plain(lambda c, k: dense_cache_append(c, k, k)),
                        cache, k_new)
    b_masked = bytes_of(
        plain(lambda c, k, a: dense_cache_append(c, k, k, active=a)),
        cache, k_new, act)
    cache_bytes = 2 * B * H * S_max * hd * 4        # K and V, f32
    assert b_masked < 0.5 * cache_bytes, (b_masked, cache_bytes)
    assert b_masked < b_nomask + 0.1 * cache_bytes

    # semantics: inactive rows untouched, active rows append at their cursor
    c0 = init_dense_cache(B, H, S_max, hd, dtype=jnp.float32)
    c0 = c0._replace(length=jnp.asarray([5, 9], jnp.int32))
    c1 = dense_cache_append(c0, k_new, 2 * k_new, active=act)
    assert c1.length.tolist() == [6, 9]
    np.testing.assert_array_equal(np.asarray(c1.k[0, :, 5]),
                                  np.ones((H, hd), np.float32))
    np.testing.assert_array_equal(np.asarray(c1.k[1]), np.zeros_like(c1.k[1]))
    np.testing.assert_array_equal(np.asarray(c1.v[1]), np.zeros_like(c1.v[1]))

    # at capacity the write is dropped AND the cursor stays put, so length
    # never claims tokens the cache doesn't hold
    c_full = init_dense_cache(B, H, 8, hd, dtype=jnp.float32)._replace(
        length=jnp.asarray([8, 3], jnp.int32))
    c2 = dense_cache_append(c_full, k_new, k_new)
    assert c2.length.tolist() == [8, 4]


def test_chunked_prefill_family_passthrough():
    """encdec/hybrid/ssm pass through: the chunked API refuses and the engine
    falls back to blocking admission for them."""
    assert M.supports_chunked_prefill(CFG)
    for family in ("hybrid", "ssm", "audio"):
        fcfg = CFG.replace(family=family)
        assert not M.supports_chunked_prefill(fcfg)
        with pytest.raises(NotImplementedError, match="blocking"):
            M.apply_prefill_chunk(None, fcfg, {}, None)
        with pytest.raises(NotImplementedError):
            M.make_prefill_chunk_state(fcfg, 1, 64, chunk=16)


def test_serve_metrics_inter_token_latency(setup):
    """ITL / TTFT percentiles are first-class serve metrics: gaps between
    consecutive token deliveries of continuing requests are recorded."""
    params = setup[0]
    eng = ServeEngine(CFG, params, runtime="retro", gen_headroom=256,
                      max_context=S, admission="chunked", prefill_chunk=128)
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(0, CFG.vocab, S).astype(np.int32),
                    max_new_tokens=8) for _ in range(3)]
    m = eng.serve(reqs, batch_size=2)
    assert len(m.step_s) > 0
    assert 0 < m.itl_p50_s <= m.itl_p99_s
    assert 0 < m.ttft_p50_s <= m.ttft_p99_s
    assert m.tokens_out == 3 * 8


def test_engine_runs_across_flush_boundary(setup):
    """Generation longer than update_segment exercises the engine flush."""
    params = setup[0]
    eng = ServeEngine(CFG, params, runtime="retro", gen_headroom=512)
    rng = np.random.default_rng(1)
    n_new = CFG.retro.update_segment + 8
    reqs = [Request(prompt=rng.integers(0, CFG.vocab, S).astype(np.int32),
                    max_new_tokens=n_new) for _ in range(2)]
    m = eng.run_wave(reqs)
    assert m.tokens_out == 2 * n_new
    for r in reqs:
        assert all(0 <= t < CFG.vocab for t in r.out_tokens)


def _serve_case(params, *, offload, frac=0.25, impl="jnp",
                admission="chunked", news=(8, 6, 20), **eng_kw):
    """Shared ragged scenario: 3 requests on 2 slots (slot reuse grafts a new
    request over a retired one), generation crossing no/one flush boundary.
    ``eng_kw`` passes retrofault knobs (fault_profile, fetch_deadline_s, ...)
    straight to the engine."""
    rng = np.random.default_rng(13)
    lens = [S, 256, 320]
    prompts = [rng.integers(0, CFG.vocab, L).astype(np.int32) for L in lens]
    eng = ServeEngine(CFG, params, runtime="retro", gen_headroom=256,
                      max_context=S, admission=admission, prefill_chunk=96,
                      attn_impl=impl, offload=offload, cache_frac=frac,
                      **eng_kw)
    reqs = [Request(prompt=p.copy(), max_new_tokens=n)
            for p, n in zip(prompts, news)]
    m = eng.serve(reqs, batch_size=2)
    return [r.out_tokens for r in reqs], m


def test_offload_serve_matches_direct(setup):
    """Acceptance: host-offload decode (device block cache + cache-slot
    indirection) reproduces the direct-store path token-for-token, and the
    serve metrics record the wave-buffer traffic."""
    params = setup[0]
    ref, m0 = _serve_case(params, offload=False)
    out, m = _serve_case(params, offload=True)
    assert out == ref
    assert m.cache_lookups > 0 and m.bytes_over_link > 0
    assert 0 < m.cache_hit_ratio <= 1
    assert m.effective_cache_hit_ratio >= m.cache_hit_ratio
    # direct path records no cache traffic
    assert m0.cache_lookups == 0 and m0.bytes_over_link == 0


@pytest.mark.slow
@pytest.mark.parametrize("admission", ("chunked", "blocking"))
@pytest.mark.parametrize("impl", ("jnp", "fused"))
def test_offload_serve_parity_matrix(setup, admission, impl):
    """Acceptance: offload == direct token-for-token across admission modes
    and attention impls (generation crosses a flush boundary: the flushed
    segments are appended to the HOST store and retrieved through the
    cache)."""
    params = setup[0]
    news = (8, 6, 41)                   # 41 crosses a flush boundary
    ref, _ = _serve_case(params, offload=False, impl=impl,
                         admission=admission, news=news)
    out, m = _serve_case(params, offload=True, impl=impl,
                         admission=admission, news=news)
    assert out == ref
    assert m.bytes_over_link > 0


def test_offload_cache_coherent_after_flush(setup):
    """Regression: rows with fewer live clusters than plan.r rank dead ids
    (top_k tie-breaks NEG scores to exactly the ids the next flush will
    allocate). Fetching those through the wave buffer would admit all-masked
    payloads that turn into STALE hits once the flush writes real blocks at
    those ids. Dead ids must never touch the buffer: after a flush-crossing
    serve, every cached cluster's payload still equals its host-store row."""
    params = setup[0]
    rng = np.random.default_rng(13)
    # prompts well short of max_context => n_clusters << plan.r every step
    prompts = [rng.integers(0, CFG.vocab, L).astype(np.int32)
               for L in (256, 200)]
    eng = ServeEngine(CFG, params, runtime="retro", gen_headroom=256,
                      max_context=S, cache_frac=0.5, offload=True)
    news = [CFG.retro.update_segment + 9, 6]     # row 0 crosses a flush
    reqs = [Request(prompt=p.copy(), max_new_tokens=n)
            for p, n in zip(prompts, news)]
    eng.serve(reqs, batch_size=2)
    plane = eng._last_plane
    checked = 0
    for per_layer in plane.bufs:
        for b, row in enumerate(per_layer):
            if row is None:
                continue
            for buf in row:
                mapped = np.where(buf.table.cache_slot >= 0)[0]
                # nothing beyond the live cluster count was ever admitted
                assert (mapped < plane.ncl[b]).all()
                for cid in mapped:
                    slot = buf.table.cache_slot[cid]
                    np.testing.assert_array_equal(buf.cache[slot],
                                                  buf.kv_host[cid])
                    checked += 1
    assert checked > 0


def test_offload_eviction_pressure(setup):
    """Cache far smaller than the per-step working set (C << r): every step
    evicts, outputs stay correct, and the link carries real traffic."""
    params = setup[0]
    ref, _ = _serve_case(params, offload=False, news=(6, 5, 8))
    out, m = _serve_case(params, offload=True, frac=0.02, news=(6, 5, 8))
    assert out == ref
    assert m.bytes_over_link > 0
    assert m.cache_hit_ratio < 0.9      # pressure: far from full reuse
    assert m.bytes_from_cache >= 0


def test_offload_zero_rate_fault_profile_is_identity(setup):
    """retrofault acceptance (faults disabled): a FaultyTransport with every
    rate at zero is a pass-through — token-identical to the direct path,
    no degraded steps, no fault counters."""
    params = setup[0]
    ref, _ = _serve_case(params, offload=False)
    out, m = _serve_case(params, offload=True, fault_profile="seed=5",
                         fetch_deadline_s=10.0)
    assert out == ref
    assert m.degraded_steps == 0 and m.dropped_cluster_steps == 0
    assert m.cache_faults == 0 and m.cache_failed_fetches == 0


def test_offload_recoverable_faults_reproduce_outputs(setup):
    """retrofault acceptance (recoverable regime): transient faults with
    ample retries and no deadline are absorbed by the retry loop — outputs
    reproduce the fault-free run exactly, with nonzero fault/retry
    telemetry and zero degraded steps."""
    params = setup[0]
    ref, _ = _serve_case(params, offload=True)
    out, m = _serve_case(params, offload=True,
                         fault_profile="transient=0.3,seed=7",
                         fetch_retries=8)
    assert out == ref
    assert m.cache_faults > 0 and m.cache_retries > 0
    assert m.degraded_steps == 0 and m.cache_failed_fetches == 0


@pytest.mark.chaos
def test_offload_chaos_soak_degrades_without_wedging(setup):
    """retrofault acceptance (degraded regime): a seeded 20%-transient
    schedule with corruption, latency spikes, no retries and a fetch
    deadline tighter than a spike. Every request still completes (no crash,
    no wedge); failed fetches are masked out of the retrieval zone and the
    telemetry records the degradation."""
    params = setup[0]
    news = (8, 6, 20)
    out, m = _serve_case(
        params, offload=True, news=news,
        fault_profile="transient=0.2,corrupt=0.02,spike=0.3,seed=3",
        fetch_retries=0, fetch_deadline_s=0.01)
    assert m.tokens_out == sum(news)
    assert [len(o) for o in out] == list(news)
    assert m.cache_faults > 0 and m.cache_failed_fetches > 0
    assert m.degraded_steps > 0
    assert m.dropped_cluster_steps >= m.degraded_steps


@pytest.mark.chaos
def test_offload_chaos_soak_seed_deterministic(setup):
    """Same seed => same fault schedule => identical outputs and identical
    degradation telemetry across runs."""
    params = setup[0]
    kw = dict(offload=True, fault_profile="transient=0.25,spike=0.3,seed=11",
              fetch_retries=1, fetch_deadline_s=0.01)
    out_a, m_a = _serve_case(params, **kw)
    out_b, m_b = _serve_case(params, **kw)
    assert out_a == out_b
    assert (m_a.cache_faults, m_a.cache_failed_fetches, m_a.degraded_steps,
            m_a.dropped_cluster_steps) == \
           (m_b.cache_faults, m_b.cache_failed_fetches, m_b.degraded_steps,
            m_b.dropped_cluster_steps)


def test_fatal_fault_finishes_request_with_error_status(setup):
    """An unrecoverable link fault poisons only the affected request: it
    finishes with status='error' (structured, no engine-wide quarantine) and
    the serve loop returns normally."""
    params = setup[0]
    rng = np.random.default_rng(13)
    prompts = [rng.integers(0, CFG.vocab, L).astype(np.int32)
               for L in (S, 256)]
    eng = ServeEngine(CFG, params, runtime="retro", gen_headroom=256,
                      max_context=S, offload=True, cache_frac=0.25,
                      fault_profile="fatal=1.0,seed=2")
    reqs = [Request(prompt=p.copy(), max_new_tokens=8) for p in prompts]
    m = eng.serve(reqs, batch_size=2)
    assert all(r.status == "error" for r in reqs)
    assert all(len(r.out_tokens) < 8 for r in reqs)
    assert m.steps >= 1                  # the loop ran and unwound cleanly


def test_watchdog_finishes_runaway_request_with_timeout(setup):
    """Per-request decode watchdog: a request that would never finish on its
    own (huge max_new_tokens) is cut off after max_decode_steps with
    status='timeout'; a short request on the same batch stays status='ok'."""
    params = setup[0]
    rng = np.random.default_rng(4)
    prompts = [rng.integers(0, CFG.vocab, 256).astype(np.int32)
               for _ in range(2)]
    eng = ServeEngine(CFG, params, runtime="retro", gen_headroom=256,
                      max_context=S, max_decode_steps=6)
    reqs = [Request(prompt=prompts[0], max_new_tokens=200),
            Request(prompt=prompts[1], max_new_tokens=3)]
    eng.serve(reqs, batch_size=2)
    assert reqs[0].status == "timeout"
    assert len(reqs[0].out_tokens) <= 7   # cut at the watchdog, not at 200
    assert reqs[1].status == "ok" and len(reqs[1].out_tokens) == 3


def test_offload_requires_retro_attention(setup):
    params = setup[0]
    with pytest.raises(ValueError, match="offload"):
        ServeEngine(CFG, params, runtime="full", offload=True)
    with pytest.raises(ValueError, match="offload"):
        ServeEngine(CFG.replace(family="ssm"), params, runtime="retro",
                    offload=True)


def test_one_token_requests_excluded_from_request_tps(setup):
    """Regression: a max_new_tokens=1 request decodes zero tokens; its 0.0
    tok/s used to be appended to request_tps, dragging down mean/percentile
    request throughput. The sample is now skipped (TTFT/tokens still count)."""
    params = setup[0]
    eng = ServeEngine(CFG, params, runtime="retro", gen_headroom=256,
                      max_context=S)
    rng = np.random.default_rng(2)
    news = [1, 5, 1]
    reqs = [Request(prompt=rng.integers(0, CFG.vocab, S).astype(np.int32),
                    max_new_tokens=n) for n in news]
    m = eng.serve(reqs, batch_size=2)
    for r, n in zip(reqs, news):
        assert r.done and len(r.out_tokens) == n
    assert m.tokens_out == sum(news)
    assert len(m.ttft_s) == 3
    # only the request that actually decoded contributes a tps sample
    assert len(m.request_tps) == 1
    assert all(t > 0 for t in m.request_tps)
    assert float(np.mean(m.request_tps)) > 0


def test_split_state_decode_matches_monolithic(setup):
    """Hot/cold split decode (§Perf iter 1) is logits-identical."""
    from repro.models.transformer import decode_step_split, split_state
    params, batch, plan, decode, _ = setup
    _, st = M.apply_prefill(params, CFG, batch, runtime="retro", plan=plan,
                            gen_headroom=256)
    tok = jnp.zeros((B,), jnp.int32)
    cold, hot = split_state(st.kv)
    split_fn = jax.jit(lambda p, c, h, t: decode_step_split(
        p, CFG, c, h, t, plan=plan))
    for _ in range(3):
        lg_m, st = decode(params, st, tok, runtime="retro")
        lg_s, hot = split_fn(params, cold, hot, tok)
        np.testing.assert_allclose(np.asarray(lg_m), np.asarray(lg_s),
                                   atol=1e-4, rtol=1e-4)
        tok = jnp.argmax(lg_m, -1).astype(jnp.int32)
