"""Sharding rules: spec-tree validity for all archs + a real multi-device
lower/compile on 8 fake CPU devices (subprocess, so the device count does not
leak into this test process)."""
import subprocess
import sys

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import INPUT_SHAPES
from repro.configs.registry import ARCH_IDS, get_config
from repro.launch import sharding as S
from repro.models import model as M


class FakeMesh:
    """Shape-only stand-in (no devices needed for rule evaluation)."""
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


MESH1 = FakeMesh({"data": 16, "model": 16})
MESH2 = FakeMesh({"pod": 2, "data": 16, "model": 16})


def _axes_valid(spec, shape, mesh):
    assert len(spec) <= len(shape)
    for dim, ax in enumerate(spec):
        if ax is None:
            continue
        axes = (ax,) if isinstance(ax, str) else tuple(ax)
        n = 1
        for a in axes:
            n *= mesh.shape[a]
        assert shape[dim] % n == 0, (spec, shape, dim, ax)


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("mesh", [MESH1, MESH2], ids=["pod1", "pod2"])
def test_param_specs_divisible(arch, mesh):
    cfg = get_config(arch)
    abs_p = M.param_specs(cfg)
    specs = S.param_pspecs(cfg, abs_p, mesh)
    assert jax.tree.structure(specs, is_leaf=lambda x: isinstance(x, P)) \
        == jax.tree.structure(abs_p)
    for leaf, spec in zip(jax.tree.leaves(abs_p),
                          jax.tree.leaves(specs,
                                          is_leaf=lambda x: isinstance(x, P))):
        _axes_valid(spec, leaf.shape, mesh)


@pytest.mark.parametrize("arch", ["gemma2_2b", "kimi_k2_1t_a32b", "rwkv6_3b",
                                  "zamba2_1p2b", "whisper_tiny"])
@pytest.mark.parametrize("shape_name", ["decode_32k", "long_500k"])
def test_serve_state_specs_divisible(arch, shape_name):
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    abs_s = M.serve_state_specs(cfg, shape.global_batch, shape.seq_len,
                                runtime="retro", gen_headroom=1024)
    specs = S.serve_state_pspecs(cfg, abs_s, MESH1, shape.global_batch)
    for leaf, spec in zip(jax.tree.leaves(abs_s),
                          jax.tree.leaves(specs,
                                          is_leaf=lambda x: isinstance(x, P))):
        _axes_valid(spec, leaf.shape, MESH1)


def test_batch_axes_fallback():
    assert S.batch_axes(MESH1, 256) == ("data",)
    assert S.batch_axes(MESH1, 1) is None
    assert S.batch_axes(MESH2, 256) == ("pod", "data")
    assert S.batch_axes(MESH2, 16) == ("data",)
    assert S.batch_axes(MESH2, 3) is None


def test_moe_expert_vs_ff_sharding():
    kimi = get_config("kimi_k2_1t_a32b")         # 384 experts % 16 == 0
    mix = get_config("mixtral_8x22b")            # 8 experts: d_ff fallback
    pk = S.param_pspecs(kimi, M.param_specs(kimi), MESH1)
    pm = S.param_pspecs(mix, M.param_specs(mix), MESH1)
    assert pk["layers"]["moe"]["w_gate"] == P(None, "model", None, None)
    assert pm["layers"]["moe"]["w_gate"] == P(None, None, None, "model")


@pytest.mark.slow
def test_multi_device_lower_compile():
    """Real 8-device lowering of serve_step for one arch (subprocess)."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
from repro.configs.base import InputShape
from repro.configs.registry import get_config, input_specs
from repro.launch import sharding as S
from repro.models import model as M
from repro.serving.steps import make_serve_step

cfg = get_config("gemma2_2b")
shape = InputShape("d", 8192, 8, "decode")
mesh = jax.make_mesh((2, 4), ("data", "model"))
step = make_serve_step(cfg, shape.seq_len, runtime="retro", gen_headroom=1024)
params_abs = M.param_specs(cfg)
state_abs = M.serve_state_specs(cfg, 8, shape.seq_len, runtime="retro",
                                gen_headroom=1024)
batch_abs = input_specs(cfg, shape)
with mesh:
    p = S.to_named(S.param_pspecs(cfg, params_abs, mesh), mesh)
    s = S.to_named(S.serve_state_pspecs(cfg, state_abs, mesh, 8), mesh)
    t = S.to_named(S.batch_pspecs(cfg, batch_abs, mesh), mesh)
    jt = jax.jit(step, in_shardings=(p, s, t["token"]), donate_argnums=(1,))
    compiled = jt.lower(params_abs, state_abs, batch_abs["token"]).compile()
cost = compiled.cost_analysis()
if isinstance(cost, list):          # older jax returns one dict per device
    cost = cost[0]
print("COMPILED_OK", cost["flops"] > 0)
"""
    import os
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    # force_host_platform_device_count only multiplies CPU devices; pinning
    # the platform also stops jax probing for a TPU (minutes of metadata
    # timeouts on TPU-toolchain images without an attached accelerator).
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=900, env=env)
    assert "COMPILED_OK True" in out.stdout, out.stderr[-3000:]
