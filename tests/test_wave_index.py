"""Wave-index construction / update invariants + retrieval quality."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import RetroConfig
from repro.core.clustering import segmented_cluster, spherical_kmeans
from repro.core.wave_index import (append_token, flush_segment,
                                   init_chunked_prefill, max_clusters,
                                   maybe_flush, prefill_append_chunk,
                                   prefill_build, prefill_finalize,
                                   prefill_layout)
from repro.core.zones import plan_zones
from repro.data.pipeline import clustered_keys

RETRO = RetroConfig(avg_cluster=8, cluster_cap=16, prefill_segment=256,
                    update_segment=128, sink=4, local=32, kmeans_iters=3)


def _build(n=1100, hd=32, B=1, H=1, seed=0):
    rng = np.random.default_rng(seed)
    k = jnp.asarray(rng.standard_normal((B, n, H, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, n, H, hd)), jnp.float32)
    M = max_clusters(n, RETRO, gen_headroom=128)
    return prefill_build(k, v, RETRO, M, dtype=jnp.float32), k, v


def test_prefill_accounting():
    state, k, v = _build()
    n = k.shape[1]
    clustered = n - RETRO.sink - RETRO.local
    assert int(state.size[0, 0].sum()) == clustered
    assert int(state.stored[0, 0].sum()) <= clustered
    assert int(state.length[0]) == n
    assert int(state.local_len[0]) == RETRO.local
    # all stored positions unique and within the clustered region
    pos = np.asarray(state.pos_store[0, 0]).reshape(-1)
    pos = pos[pos >= 0]
    assert len(np.unique(pos)) == len(pos)
    assert pos.min() >= RETRO.sink and pos.max() < n - RETRO.local


def test_vsum_matches_members():
    """Meta-index value sums equal the sum of member values (incl. overflow)."""
    state, k, v = _build(n=612, seed=2)
    active = int(state.n_clusters[0])
    vs = np.asarray(state.vsum[0, 0][:active])
    pos = np.asarray(state.pos_store[0, 0][:active])
    size = np.asarray(state.size[0, 0][:active])
    stored = np.asarray(state.stored[0, 0][:active])
    vals = np.asarray(v[0, :, 0])
    full = size == stored                   # clusters without overflow
    for i in np.where(full)[0]:
        p = pos[i][pos[i] >= 0]
        np.testing.assert_allclose(vs[i], vals[p].sum(0), rtol=1e-4, atol=1e-4)


def test_centroid_is_member_mean():
    state, k, v = _build(n=612, seed=4)
    active = int(state.n_clusters[0])
    cent = np.asarray(state.centroid[0, 0][:active])
    pos = np.asarray(state.pos_store[0, 0][:active])
    size = np.asarray(state.size[0, 0][:active])
    stored = np.asarray(state.stored[0, 0][:active])
    keys = np.asarray(k[0, :, 0])
    for i in np.where(size == stored)[0][:20]:
        p = pos[i][pos[i] >= 0]
        np.testing.assert_allclose(cent[i], keys[p].mean(0), rtol=1e-4,
                                   atol=1e-4)


def test_decode_append_and_flush():
    state, k, v = _build()
    n0 = int(state.n_clusters[0])
    B, H, hd = 1, 1, 32
    lbuf = RETRO.local + RETRO.update_segment
    rng = np.random.default_rng(9)
    for t in range(RETRO.update_segment):
        kn = jnp.asarray(rng.standard_normal((B, H, hd)), jnp.float32)
        state = append_token(state, kn, kn)
    assert int(state.local_len[0]) == lbuf
    state = flush_segment(state, RETRO)
    assert int(state.n_clusters[0]) == n0 + RETRO.update_segment // RETRO.avg_cluster
    assert int(state.local_len[0]) == RETRO.local
    # flushed clusters carry correct positions
    new = np.asarray(state.pos_store[0, 0][n0:int(state.n_clusters[0])])
    got = np.sort(new[new >= 0])
    n = k.shape[1]
    expect = np.arange(n - RETRO.local, n - RETRO.local + RETRO.update_segment)
    np.testing.assert_array_equal(got, expect)


def test_maybe_flush_noop_when_not_full():
    state, _, _ = _build()
    out = maybe_flush(state, RETRO)
    assert int(out.n_clusters[0]) == int(state.n_clusters[0])


def test_segmented_vs_global_recall():
    """Paper Fig. 19b: segmented clustering keeps retrieval recall close to
    global k-means on spatially-local key fields."""
    n, hd = 2048, 32
    keys, q, hot = clustered_keys(n, hd, n_hot=6, seed=0)
    kj = jnp.asarray(keys)
    scores = keys @ q
    top100 = np.argsort(-scores)[:100]

    def recall(res, r):
        csc = np.asarray(res.centroid) @ q
        order = np.argsort(-csc)[:r]
        pos = np.asarray(res.pos_store)[order].reshape(-1)
        sel = set(pos[pos >= 0].tolist())
        return np.mean([t in sel for t in top100])

    vv = jnp.asarray(np.zeros_like(keys))
    pos = jnp.arange(n, dtype=jnp.int32)
    seg = segmented_cluster(kj, vv, pos, 256, 8, 16, 5, True)
    r = max(8, int(0.1 * n // 8))
    rec_seg = recall(seg, r)
    # global k-means (single segment)
    glob = segmented_cluster(kj, vv, pos, n, 8, 16, 5, True)
    rec_glob = recall(glob, r)
    assert rec_seg >= 0.9
    assert rec_seg >= rec_glob - 0.05      # within 5% of global (paper: <1%)


def test_overflow_rate_is_low():
    """cap = 2x avg keeps the store-truncation rate small (DESIGN §2)."""
    n, hd = 2048, 32
    keys, _, _ = clustered_keys(n, hd, n_hot=4, seed=1)
    vv = jnp.asarray(np.zeros_like(keys))
    pos = jnp.arange(n, dtype=jnp.int32)
    res = segmented_cluster(jnp.asarray(keys), vv, pos, 256, 8, 16, 5, True)
    dropped = 1.0 - int(res.stored.sum()) / int(res.size.sum())
    assert dropped < 0.10


def test_layout_and_padding():
    nf, tail, m = prefill_layout(1100, RETRO)
    assert nf == 4 and tail == 1100 - 36 - 4 * 256
    M = max_clusters(1100, RETRO, gen_headroom=128, pad_multiple=256)
    assert M % 256 == 0 and M >= m


def test_short_prompt_layout_degenerates():
    """Regression: prompts shorter than sink + local used to produce NEGATIVE
    full-segment / cluster counts (floor division of a negative region). The
    layout must clamp to a steady-zone-only plan and the zone plan / store
    sizing must stay usable."""
    nf, tail, m = prefill_layout(64, RetroConfig())       # sink=4, local=64
    assert (nf, tail, m) == (0, 0, 0)
    for s in (1, 4, 67, 68, 69):
        nf, tail, m = prefill_layout(s, RetroConfig())
        assert nf >= 0 and tail >= 0 and m >= 0
    M = max_clusters(64, RetroConfig())
    assert M > 0 and M % 256 == 0
    plan = plan_zones(64, RetroConfig())
    assert plan.r == 0 and plan.e == 0 and plan.m_max == M


def test_prompt_not_longer_than_sink_rejected():
    """S <= sink cannot fill the fixed-width sink zone (implicit arange
    positions): the builder must refuse instead of leaving attendable
    zero-key slots."""
    hd = 16
    rng = np.random.default_rng(0)
    k = jnp.asarray(rng.standard_normal((1, RETRO.sink, 1, hd)), jnp.float32)
    with pytest.raises(ValueError, match="sink"):
        prefill_build(k, k, RETRO, 256, dtype=jnp.float32)


def test_short_prompt_prefill_build():
    """A prompt shorter than sink + local builds a steady-zone-only state:
    no clusters, the local window covers everything past the sinks."""
    n, hd = 20, 16
    rng = np.random.default_rng(3)
    k = jnp.asarray(rng.standard_normal((1, n, 1, hd)), jnp.float32)
    M = max_clusters(n, RETRO, gen_headroom=128)
    state = prefill_build(k, k, RETRO, M, dtype=jnp.float32)
    assert int(state.n_clusters[0]) == 0
    assert int(state.length[0]) == n
    assert int(state.local_len[0]) == n - RETRO.sink


def test_ragged_prefill_build_masks_padding():
    """Right-padded ragged build: pad tokens never enter any store, each
    row's clustered region ends exactly ``local`` before its true length."""
    B, S, hd = 3, 640, 16
    lens = np.array([640, 417, 300], np.int32)
    rng = np.random.default_rng(5)
    k = jnp.asarray(rng.standard_normal((B, S, 1, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, 1, hd)), jnp.float32)
    M = max_clusters(S, RETRO, gen_headroom=128)
    state = prefill_build(k, v, RETRO, M, dtype=jnp.float32,
                          lengths=jnp.asarray(lens))
    np.testing.assert_array_equal(np.asarray(state.length), lens)
    for b in range(B):
        clustered = int(np.asarray(state.size[b, 0]).sum())
        assert clustered == lens[b] - RETRO.sink - RETRO.local
        pos = np.asarray(state.pos_store[b, 0]).reshape(-1)
        pos = pos[pos >= 0]
        assert len(np.unique(pos)) == len(pos)
        assert pos.min() >= RETRO.sink
        assert pos.max() < lens[b] - RETRO.local          # pads excluded


def test_per_row_masked_flush():
    """Rows flush independently: only rows with a full staging buffer gain
    clusters; the others are bit-unchanged."""
    state, k, v = _build(B=2, H=1)
    n0 = int(state.n_clusters[0])
    rng = np.random.default_rng(11)
    hd = 32
    # row 0 appends a full update segment; row 1 stays behind by one token
    for t in range(RETRO.update_segment):
        kn = jnp.asarray(rng.standard_normal((2, 1, hd)), jnp.float32)
        act = jnp.asarray([True, t < RETRO.update_segment - 1])
        state = append_token(state, kn, kn, active=act)
    lbuf = RETRO.local + RETRO.update_segment
    np.testing.assert_array_equal(np.asarray(state.local_len), [lbuf, lbuf - 1])
    before_row1 = jax.tree.map(lambda a: np.asarray(a[1]), state)
    out = flush_segment(state, RETRO)
    assert int(out.n_clusters[0]) == n0 + RETRO.update_segment // RETRO.avg_cluster
    assert int(out.n_clusters[1]) == n0                   # row 1 untouched
    assert int(out.local_len[0]) == RETRO.local
    assert int(out.local_len[1]) == lbuf - 1
    after_row1 = jax.tree.map(lambda a: np.asarray(a[1]), out)
    for name, a, b in zip(out._fields, before_row1, after_row1):
        np.testing.assert_array_equal(a, b, err_msg=name)


def _feed_chunks(cp, k, v, C, jit=False):
    """Stream (B, n, H, hd) K/V through prefill_append_chunk in C-sized
    chunks (last chunk right-padded)."""
    B, n, H, hd = k.shape
    app = prefill_append_chunk
    if jit:
        app = jax.jit(lambda cp, kc, vc, cl: prefill_append_chunk(
            cp, kc, vc, RETRO, cl))
    t = 0
    while t < n:
        c = min(C, n - t)
        kc = jnp.zeros((B, C, H, hd), k.dtype).at[:, :c].set(k[:, t:t + c])
        vc = jnp.zeros((B, C, H, hd), v.dtype).at[:, :c].set(v[:, t:t + c])
        cl = jnp.full((B,), c, jnp.int32)
        cp = app(cp, kc, vc, cl) if jit else app(cp, kc, vc, RETRO, cl)
        t += c
    return cp


def _assert_states_equal(out, ref):
    for f, a, b in zip(out._fields, out, ref):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f)


@pytest.mark.parametrize("chunk", (96, 256, 300, 1100))
def test_chunked_prefill_matches_build_exactly(chunk):
    """Acceptance: streaming the prompt through prefill_append_chunk +
    prefill_finalize reproduces prefill_build BIT-IDENTICALLY for any chunk
    split — segment boundaries are position-aligned, not chunk-aligned."""
    ref, k, v = _build()
    B, n, H, hd = k.shape
    M = ref.k_store.shape[2]
    cp = init_chunked_prefill(B, H, hd, M, RETRO, chunk, dtype=jnp.float32)
    cp = _feed_chunks(cp, k, v, chunk, jit=(chunk == 256))
    out = prefill_finalize(cp, RETRO, n)
    _assert_states_equal(out, ref)


@pytest.mark.slow
def test_chunked_prefill_per_row_rates():
    """Rows of one batch may stream at different rates (per-row chunk_lens);
    once they converge to the same total the state matches the monolithic
    build row-for-row."""
    B, n, H, hd = 2, 1100, 1, 32
    rng = np.random.default_rng(0)
    k = jnp.asarray(rng.standard_normal((B, n, H, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, n, H, hd)), jnp.float32)
    M = max_clusters(n, RETRO, gen_headroom=128)
    ref = prefill_build(k, v, RETRO, M, dtype=jnp.float32)

    C = 128
    cp = init_chunked_prefill(B, H, hd, M, RETRO, C, dtype=jnp.float32)
    t = np.zeros(B, int)
    rng2 = np.random.default_rng(1)
    while (t < n).any():
        cl = np.minimum(rng2.integers(0, C + 1, B), n - t)
        kc = jnp.zeros((B, C, H, hd), jnp.float32)
        vc = jnp.zeros((B, C, H, hd), jnp.float32)
        for b in range(B):
            kc = kc.at[b, :cl[b]].set(k[b, t[b]:t[b] + cl[b]])
            vc = vc.at[b, :cl[b]].set(v[b, t[b]:t[b] + cl[b]])
        cp = prefill_append_chunk(cp, kc, vc, RETRO,
                                  jnp.asarray(cl, jnp.int32))
        t += cl
    out = prefill_finalize(cp, RETRO, n)
    _assert_states_equal(out, ref)


def test_chunked_prefill_short_prompt():
    """A streamed prompt shorter than sink + local finalizes to the same
    steady-zone-only state as the monolithic build."""
    B, n, H, hd = 1, 20, 1, 16
    rng = np.random.default_rng(3)
    k = jnp.asarray(rng.standard_normal((B, n, H, hd)), jnp.float32)
    M = max_clusters(n, RETRO, gen_headroom=128)
    ref = prefill_build(k, k, RETRO, M, dtype=jnp.float32)
    cp = init_chunked_prefill(B, H, hd, M, RETRO, 16, dtype=jnp.float32)
    cp = _feed_chunks(cp, k, k, 16)
    out = prefill_finalize(cp, RETRO, n)
    _assert_states_equal(out, ref)
    assert int(out.n_clusters[0]) == 0
    assert int(out.local_len[0]) == n - RETRO.sink


def test_chunked_finalize_rejects_sink_only_prompt():
    """Same contract as prefill_build: a prompt that cannot overfill the
    fixed-width sink zone is refused."""
    cp = init_chunked_prefill(1, 1, 16, 256, RETRO, 4, dtype=jnp.float32)
    k = jnp.zeros((1, 4, 1, 16), jnp.float32)
    cp = prefill_append_chunk(cp, k, k, RETRO)
    with pytest.raises(ValueError, match="sink"):
        prefill_finalize(cp, RETRO, RETRO.sink)


def test_kmeans_clusters_separable_data():
    rng = np.random.default_rng(0)
    centers = rng.standard_normal((4, 16)) * 4
    pts = np.concatenate([c + 0.05 * rng.standard_normal((32, 16))
                          for c in centers])
    assign, cent = spherical_kmeans(jnp.asarray(pts, jnp.float32), 4, 8)
    a = np.asarray(assign)
    for g in range(4):
        grp = a[g * 32:(g + 1) * 32]
        assert len(np.unique(grp)) == 1      # each blob in one cluster
