"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.gather.ops import block_gather_op
from repro.kernels.gather.ref import block_gather_ref
from repro.kernels.kmeans.ops import segmented_kmeans_op
from repro.kernels.kmeans.ref import kmeans_ref
from repro.kernels.wave_attention.kernel import NEG
from repro.kernels.wave_attention.ops import wave_attention_merge
from repro.kernels.wave_attention.ref import wave_attention_ref

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("B,H,G,hd,T,E,softcap", [
    (2, 2, 2, 32, 300, 24, None),
    (1, 4, 8, 64, 1024, 100, 50.0),
    (2, 1, 1, 128, 77, 5, None),
    (1, 2, 4, 256, 513, 64, None),
    (3, 3, 2, 64, 128, 1, 30.0),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_wave_attention_kernel(B, H, G, hd, T, E, softcap, dtype):
    ks = jax.random.split(jax.random.fold_in(KEY, T * E), 8)
    q = jax.random.normal(ks[0], (B, H, G, hd), dtype)
    k = jax.random.normal(ks[1], (B, H, T, hd), dtype)
    v = jax.random.normal(ks[2], (B, H, T, hd), dtype)
    valid = jax.random.bernoulli(ks[3], 0.8, (B, H, T))
    el = jax.random.normal(ks[4], (B, H, G, E)) * 2
    cs = el - jnp.abs(jax.random.normal(ks[5], (B, H, G, E)))
    el = jnp.where(jax.random.bernoulli(ks[6], 0.9, (B, H, G, E)), el, NEG)
    vs = jax.random.normal(ks[7], (B, H, E, hd)) * 3
    out = wave_attention_merge(q, k, v, valid, el, cs, vs, softcap=softcap,
                               interpret=True)
    ref = wave_attention_ref(
        q.reshape(B * H, G, hd), k.reshape(B * H, T, hd),
        v.reshape(B * H, T, hd), valid.reshape(B * H, T).astype(jnp.int32),
        el.reshape(B * H, G, E), cs.reshape(B * H, G, E),
        vs.reshape(B * H, E, hd), softcap=softcap)
    tol = 5e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out).reshape(B * H, G, hd),
                               np.asarray(ref), atol=tol, rtol=tol)


def test_wave_attention_all_invalid_est():
    """Estimation zone fully masked => pure exact attention."""
    B, H, G, hd, T, E = 1, 1, 2, 32, 128, 8
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, G, hd))
    k = jax.random.normal(ks[1], (B, H, T, hd))
    v = jax.random.normal(ks[2], (B, H, T, hd))
    valid = jnp.ones((B, H, T), bool)
    el = jnp.full((B, H, G, E), NEG)
    vs = jnp.zeros((B, H, E, hd))
    out = wave_attention_merge(q, k, v, valid, el, el, vs, interpret=True)
    s = jnp.einsum("bhgd,bhtd->bhgt", q, k) / np.sqrt(hd)
    ref = jnp.einsum("bhgt,bhtd->bhgd", jax.nn.softmax(s, -1), v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


@pytest.mark.parametrize("S,n,d,k,iters", [
    (4, 256, 32, 16, 4), (2, 128, 64, 8, 3), (1, 512, 128, 64, 2),
    (8, 64, 16, 8, 5),
])
def test_kmeans_kernel(S, n, d, k, iters):
    x = jax.random.normal(jax.random.fold_in(KEY, S * n), (S, n, d))
    c0 = x[:, :: max(1, n // k)][:, :k]
    cp, ap = segmented_kmeans_op(x, c0, iters=iters, interpret=True)
    cr, ar = kmeans_ref(x, c0, iters)
    np.testing.assert_allclose(np.asarray(cp), np.asarray(cr), atol=1e-5)
    assert np.mean(np.asarray(ap) == np.asarray(ar)) == 1.0


@pytest.mark.parametrize("B,H,M,cap,hd,r", [
    (2, 2, 64, 16, 32, 8), (1, 1, 128, 32, 64, 13), (4, 2, 32, 8, 128, 32),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gather_kernel(B, H, M, cap, hd, r, dtype):
    ks = jax.random.split(jax.random.fold_in(KEY, M * r), 3)
    kst = jax.random.normal(ks[0], (B, H, M, cap, hd), dtype)
    vst = jax.random.normal(ks[1], (B, H, M, cap, hd), dtype)
    idx = jax.random.randint(ks[2], (B, H, r), 0, M)
    ko, vo = block_gather_op(idx, kst, vst, interpret=True)
    kr, vr = block_gather_ref(idx.reshape(B * H, r),
                              kst.reshape(B * H, M, cap, hd),
                              vst.reshape(B * H, M, cap, hd))
    np.testing.assert_array_equal(np.asarray(ko).reshape(B * H, r, cap, hd),
                                  np.asarray(kr))
    np.testing.assert_array_equal(np.asarray(vo).reshape(B * H, r, cap, hd),
                                  np.asarray(vr))


def test_gather_repeated_indices():
    """Duplicate cluster ids must replicate blocks (cache-hit path)."""
    kst = jnp.arange(4 * 2 * 8, dtype=jnp.float32).reshape(1, 1, 4, 2, 8)
    idx = jnp.asarray([[[2, 2, 0]]])
    ko, _ = block_gather_op(idx, kst, kst, interpret=True)
    np.testing.assert_array_equal(np.asarray(ko[0, 0, 0]),
                                  np.asarray(ko[0, 0, 1]))
    np.testing.assert_array_equal(np.asarray(ko[0, 0, 2]),
                                  np.asarray(kst[0, 0, 0]))


# ---------------------------------------------------------------------------
# Gather-free paged kernel: parity vs the reference execution-buffer path.
# Both flavors are exercised: the actual Pallas kernel through the
# interpreter (emulate=False) and the jnp zone-walk emulation the CPU
# serving path resolves to (emulate=True).
# ---------------------------------------------------------------------------


def _paged_state(G=4, n=640, B=2, H=2, hd=32, seed=0, lengths=None,
                 retro_kw=None, n_append=0):
    from repro.configs.base import RetroConfig
    from repro.core.wave_index import append_token, prefill_build
    from repro.core.zones import plan_zones

    kw = dict(avg_cluster=8, cluster_cap=16, prefill_segment=256,
              update_segment=128, sink=4, local=32, kmeans_iters=3)
    kw.update(retro_kw or {})
    retro = RetroConfig(**kw)
    rng = np.random.default_rng(seed)
    k = jnp.asarray(rng.standard_normal((B, n, H, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, n, H, hd)), jnp.float32)
    plan = plan_zones(n, retro, 128)
    state = prefill_build(k, v, retro, plan.m_max, dtype=jnp.float32,
                          lengths=lengths)
    for i in range(n_append):
        kn = jnp.asarray(rng.standard_normal((B, H, hd)), jnp.float32)
        state = append_token(state, kn, kn)
    q = jnp.asarray(rng.standard_normal((B, G * H, hd)), jnp.float32)
    return q, state, retro, plan


def _paged_parity(q, state, retro, plan, emulate, double_buffer=True, **kw):
    from unittest import mock

    from repro.core.attention import wave_attention_decode
    from repro.kernels.wave_attention import ops as wa_ops

    o_ref = wave_attention_decode(q, state, retro, plan, impl="jnp", **kw).out
    orig = wa_ops.paged_wave_attention

    def forced(*a, **k):
        k["emulate"] = emulate
        k["double_buffer"] = double_buffer
        return orig(*a, **k)

    with mock.patch.object(wa_ops, "paged_wave_attention", forced):
        o_fused = wave_attention_decode(q, state, retro, plan, impl="fused",
                                        **kw).out
    np.testing.assert_allclose(np.asarray(o_ref), np.asarray(o_fused),
                               atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("emulate", [False, True],
                         ids=["pallas-interpret", "jnp-emulation"])
@pytest.mark.parametrize("G", [1, 4, 8])
def test_paged_kernel_parity_gqa(G, emulate):
    q, state, retro, plan = _paged_state(G=G)
    _paged_parity(q, state, retro, plan, emulate)


@pytest.mark.parametrize("double_buffer", [False, True],
                         ids=["blockspec-walk", "double-buffered-dma"])
def test_paged_kernel_cluster_walk_flavors(double_buffer):
    """Both cluster-walk flavors of the paged kernel — the per-cluster
    BlockSpec walk and the double-buffered manual-DMA walk (prefetch cluster
    j+1 while folding j) — agree with the reference execution-buffer path
    through the interpreter."""
    q, state, retro, plan = _paged_state(G=4, seed=17)
    _paged_parity(q, state, retro, plan, emulate=False,
                  double_buffer=double_buffer)


@pytest.mark.parametrize("double_buffer", [False, True],
                         ids=["blockspec-walk", "double-buffered-dma"])
def test_paged_kernel_walks_on_cache_slots(double_buffer):
    """Cache-slot indirection: the kernel is agnostic to WHAT the id-addressed
    block store is — permuting the blocks into a 'cache' store and passing
    translated slots reproduces the direct-store result bit-for-bit."""
    from repro.kernels.wave_attention import ops as wa_ops

    q, state, retro, plan = _paged_state(G=2, seed=21)
    from repro.core.attention import wave_decode_rank
    B, H = state.k_store.shape[:2]
    G = q.shape[1] // H
    qg = q.reshape(B, H, G, q.shape[-1])
    idx_r, el, cs, vs = wave_decode_rank(qg, state, retro, plan)
    r = idx_r.shape[2]
    assert r > 0

    from repro.core.attention import wave_attention_attend

    # build a slot store: slot s of row (b, h) holds cluster idx_r[b, h, s]
    take = lambda a: jnp.take_along_axis(
        a, idx_r.reshape(idx_r.shape + (1,) * (a.ndim - 3)), axis=2)
    cache = (take(state.k_store), take(state.v_store), take(state.pos_store))
    slots = jnp.broadcast_to(jnp.arange(r, dtype=jnp.int32), idx_r.shape)
    import unittest.mock as mock
    orig = wa_ops.paged_wave_attention

    def forced(*a, **k):
        k["double_buffer"] = double_buffer
        k["emulate"] = False
        return orig(*a, **k)

    with mock.patch.object(wa_ops, "paged_wave_attention", forced):
        direct = wave_attention_attend(q, state, retro, plan, idx_r, el, cs,
                                       vs, impl="fused").out
        via_cache = wave_attention_attend(q, state, retro, plan, slots, el,
                                          cs, vs, kv_src=cache,
                                          impl="fused").out
    np.testing.assert_array_equal(np.asarray(direct), np.asarray(via_cache))


@pytest.mark.parametrize("emulate", [False, True],
                         ids=["pallas-interpret", "jnp-emulation"])
@pytest.mark.parametrize("softcap,window", [(30.0, None), (None, 200.0),
                                            (50.0, 128.0)])
def test_paged_kernel_parity_softcap_window(softcap, window, emulate):
    q, state, retro, plan = _paged_state(seed=3)
    w = None if window is None else jnp.float32(window)
    _paged_parity(q, state, retro, plan, emulate, softcap=softcap, window=w)


@pytest.mark.parametrize("emulate", [False, True],
                         ids=["pallas-interpret", "jnp-emulation"])
@pytest.mark.parametrize("use_est,overflow", [(True, True), (True, False),
                                              (False, False)])
def test_paged_kernel_parity_estimation_toggles(use_est, overflow, emulate):
    q, state, retro, plan = _paged_state(seed=5)
    _paged_parity(q, state, retro, plan, emulate, use_estimation=use_est,
                  overflow_correction=overflow)


@pytest.mark.parametrize("emulate", [False, True],
                         ids=["pallas-interpret", "jnp-emulation"])
def test_paged_kernel_parity_plan_e_zero(emulate):
    """Full retrieval coverage => plan.e == 0 (no estimation zone)."""
    q, state, retro, plan = _paged_state(
        seed=7, retro_kw=dict(cluster_cap=64, prefill_segment=64,
                              update_segment=32, retrieval_frac=1.0,
                              estimation_frac=0.0))
    assert plan.e == 0
    _paged_parity(q, state, retro, plan, emulate)


@pytest.mark.parametrize("emulate", [False, True],
                         ids=["pallas-interpret", "jnp-emulation"])
def test_paged_kernel_parity_ragged_rows(emulate):
    """Per-row lengths + appended decode tokens: rows sit at different
    positions with partially filled local buffers."""
    q, state, retro, plan = _paged_state(
        seed=9, n=512, lengths=jnp.asarray([512, 300], jnp.int32), n_append=5)
    _paged_parity(q, state, retro, plan, emulate)


@pytest.mark.parametrize("emulate", [False, True],
                         ids=["pallas-interpret", "jnp-emulation"])
def test_paged_kernel_parity_steady_only(emulate):
    """Prompt shorter than sink + local => r = e = 0; the fused path pads a
    dead retrieval slot that the live mask must kill."""
    q, state, retro, plan = _paged_state(
        seed=11, n=24, retro_kw=dict(local=64))
    assert plan.r == 0 and plan.e == 0
    _paged_parity(q, state, retro, plan, emulate)


def test_paged_decode_no_gather_temp():
    """Acceptance: the jitted fused decode emits no (B*H, r, cap, hd) gather
    temp, and its cost_analysis bytes-accessed drops vs the jnp path."""
    import re

    from repro.core.attention import wave_attention_decode

    q, state, retro, plan = _paged_state(G=2, n=2048, retro_kw=dict(
        avg_cluster=16, cluster_cap=32, retrieval_frac=0.35))
    B, H = state.k_store.shape[:2]
    gather_shapes = [f"{B},{H},{plan.r},{retro.cluster_cap}",
                     f"{B * H},{plan.r},{retro.cluster_cap}"]

    def compiled(impl):
        fn = jax.jit(lambda q, st: wave_attention_decode(
            q, st, retro, plan, impl=impl).out)
        return fn.lower(q, state).compile()

    from conftest import cost_bytes
    c_jnp, c_fused = compiled("jnp"), compiled("fused")
    hlo = c_fused.as_text()
    for shape in gather_shapes:
        assert not re.search(rf"\[{shape},\d+\]", hlo), shape
    assert cost_bytes(c_fused) < cost_bytes(c_jnp)


def test_wave_attention_kernel_matches_core_merge():
    """The kernel path (impl='pallas') plugged into the full tripartite
    attention equals the jnp path on identical state."""
    from repro.configs.base import RetroConfig
    from repro.core.attention import wave_attention_decode
    from repro.core.wave_index import max_clusters, prefill_build
    from repro.core.zones import plan_zones

    retro = RetroConfig(avg_cluster=8, cluster_cap=16, prefill_segment=256,
                        update_segment=128, sink=4, local=32, kmeans_iters=3)
    rng = np.random.default_rng(0)
    B, n, H, hd = 2, 640, 2, 32
    k = jnp.asarray(rng.standard_normal((B, n, H, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, n, H, hd)), jnp.float32)
    state = prefill_build(k, v, retro, max_clusters(n, retro, 128),
                          dtype=jnp.float32)
    q = jnp.asarray(rng.standard_normal((B, 2 * H, hd)), jnp.float32)
    plan = plan_zones(n, retro, 128)
    o_jnp = wave_attention_decode(q, state, retro, plan, impl="jnp").out
    o_pal = wave_attention_decode(q, state, retro, plan, impl="pallas").out
    np.testing.assert_allclose(np.asarray(o_jnp), np.asarray(o_pal),
                               atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# Degraded decode (retrofault): per-cluster validity mask + estimation cover.
# ---------------------------------------------------------------------------


def _rank_with_cover(q, state, retro, plan):
    from repro.core.attention import wave_decode_rank
    B, H = state.k_store.shape[:2]
    qg = q.reshape(B, H, q.shape[1] // H, q.shape[-1])
    return wave_decode_rank(qg, state, retro, plan, with_cover=True)


@pytest.mark.parametrize("emulate", [False, True],
                         ids=["pallas-interpret", "jnp-emulation"])
def test_paged_kernel_all_valid_mask_is_bit_identical(emulate):
    """Degraded-capable attend with an ALL-VALID mask must be token-for-token
    (here: bit-for-bit) identical to the maskless path on both impls: the
    gated cover entries are NEG/zero and contribute exactly 0.0."""
    from unittest import mock

    from repro.core.attention import wave_attention_attend
    from repro.kernels.wave_attention import ops as wa_ops

    q, state, retro, plan = _paged_state(G=2, seed=23)
    idx_r, el, cs, vs, cover = _rank_with_cover(q, state, retro, plan)
    valid = jnp.ones(idx_r.shape, jnp.int32)
    orig = wa_ops.paged_wave_attention

    def forced(*a, **k):
        k["emulate"] = emulate
        return orig(*a, **k)

    with mock.patch.object(wa_ops, "paged_wave_attention", forced):
        for impl in ("jnp", "fused"):
            base = wave_attention_attend(q, state, retro, plan, idx_r, el,
                                         cs, vs, impl=impl).out
            masked = wave_attention_attend(q, state, retro, plan, idx_r, el,
                                           cs, vs, impl=impl, valid=valid,
                                           cover=cover).out
            np.testing.assert_array_equal(np.asarray(base),
                                          np.asarray(masked))


@pytest.mark.parametrize("emulate", [False, True],
                         ids=["pallas-interpret", "jnp-emulation"])
def test_paged_kernel_validity_mask_parity(emulate):
    """Mixed validity mask (fetch-failed clusters dropped from the retrieval
    zone, covered by the estimation zone): the fused paged kernel agrees with
    the reference execution-buffer path."""
    from unittest import mock

    from repro.core.attention import wave_attention_attend
    from repro.kernels.wave_attention import ops as wa_ops

    q, state, retro, plan = _paged_state(G=2, seed=29)
    idx_r, el, cs, vs, cover = _rank_with_cover(q, state, retro, plan)
    rng = np.random.default_rng(31)
    valid = jnp.asarray(rng.integers(0, 2, idx_r.shape), jnp.int32)
    o_jnp = wave_attention_attend(q, state, retro, plan, idx_r, el, cs, vs,
                                  impl="jnp", valid=valid, cover=cover).out
    orig = wa_ops.paged_wave_attention

    def forced(*a, **k):
        k["emulate"] = emulate
        return orig(*a, **k)

    with mock.patch.object(wa_ops, "paged_wave_attention", forced):
        o_fused = wave_attention_attend(q, state, retro, plan, idx_r, el, cs,
                                        vs, impl="fused", valid=valid,
                                        cover=cover).out
    np.testing.assert_allclose(np.asarray(o_jnp), np.asarray(o_fused),
                               atol=1e-5, rtol=1e-5)


def test_validity_mask_equals_physical_block_removal():
    """The mask's retrieval-zone semantics alone (no cover): masking cluster
    j out is bit-equal to handing the attend a block store whose slot j is a
    dead (pos = -1) block — the degraded step attends over exactly the
    blocks that arrived."""
    from repro.core.attention import wave_attention_attend

    q, state, retro, plan = _paged_state(G=2, seed=37)
    idx_r, el, cs, vs, _ = _rank_with_cover(q, state, retro, plan)
    B, H, r = idx_r.shape
    rng = np.random.default_rng(41)
    valid = jnp.asarray(rng.integers(0, 2, (B, H, r)), jnp.int32)

    take = lambda a: jnp.take_along_axis(
        a, idx_r.reshape(idx_r.shape + (1,) * (a.ndim - 3)), axis=2)
    kb, vb, pb = take(state.k_store), take(state.v_store), take(state.pos_store)
    slots = jnp.broadcast_to(jnp.arange(r, dtype=jnp.int32), idx_r.shape)
    masked = wave_attention_attend(q, state, retro, plan, slots, el, cs, vs,
                                   kv_src=(kb, vb, pb), impl="jnp",
                                   valid=valid).out
    pb_dead = jnp.where(valid[..., None] > 0, pb, -1)
    removed = wave_attention_attend(q, state, retro, plan, slots, el, cs, vs,
                                    kv_src=(kb, vb, pb_dead), impl="jnp").out
    np.testing.assert_array_equal(np.asarray(masked), np.asarray(removed))


# ---------------------------------------------------------------------------
# retronum (PR 10) property tests: the numerics contract the RL4xx checker
# certifies structurally, verified numerically on the real zone walk.
# ---------------------------------------------------------------------------


def test_online_softmax_fold_mass_conservation():
    """Mass conservation across the sink/local/retrieved/estimation walk:
    with every value vector == 1 (and vsum = size accordingly), the fold's
    output is exactly num/den = 1 in f32 — any rescale that loses or
    double-counts exp-weight mass (max updates, estimation-zone fold,
    overflow correction) breaks the identity."""
    from unittest import mock

    from repro.core.attention import wave_attention_decode
    from repro.kernels.wave_attention import ops as wa_ops

    q, state, retro, plan = _paged_state(G=4, seed=11, retro_kw=dict(
        retrieval_frac=0.1, estimation_frac=0.4))
    ones = {f: jnp.ones_like(getattr(state, f))
            for f in ("v_store", "sink_v", "local_v")}
    vsum = state.size.astype(jnp.float32)[..., None] * jnp.ones_like(
        state.vsum)
    state = state._replace(vsum=vsum, **ones)

    def fold(impl, emulate=None):
        if emulate is None:
            return wave_attention_decode(q, state, retro, plan,
                                         impl=impl).out
        orig = wa_ops.paged_wave_attention

        def forced(*a, **k):
            k["emulate"] = emulate
            return orig(*a, **k)
        with mock.patch.object(wa_ops, "paged_wave_attention", forced):
            return wave_attention_decode(q, state, retro, plan,
                                         impl="fused").out

    for label, out in (("jnp", fold("jnp")),
                       ("fused-emulation", fold("fused", emulate=True)),
                       ("pallas-interpret", fold("fused", emulate=False))):
        np.testing.assert_allclose(np.asarray(out), 1.0, atol=1e-5,
                                   err_msg=f"mass not conserved ({label})")


def test_bf16_store_decode_divergence_bound():
    """bf16 payload stores vs f32 stores through the full zone walk: the
    meta index (centroids/vsum) stays f32, so ranking is identical and the
    divergence is pure payload rounding — bounded by a few bf16 ulps of the
    O(1)-magnitude attention output, and nonzero (the cast is real)."""
    from repro.core.attention import wave_attention_decode

    q, state, retro, plan = _paged_state(G=2, seed=23, retro_kw=dict(
        retrieval_frac=0.1, estimation_frac=0.3))
    payload = ("k_store", "v_store", "sink_k", "sink_v",
               "local_k", "local_v")
    state16 = state._replace(**{
        f: getattr(state, f).astype(jnp.bfloat16) for f in payload})

    for impl in ("jnp", "fused"):
        o32 = wave_attention_decode(q, state, retro, plan, impl=impl).out
        o16 = wave_attention_decode(q, state16, retro, plan, impl=impl).out
        diff = np.max(np.abs(np.asarray(o32) - np.asarray(o16)))
        assert 0.0 < diff < 5e-2, (impl, diff)


def test_dense_decode_storage_dtype_bytes():
    """RL402 dense-path regression (the retronum catch this PR fixed): the
    storage-dtype + preferred_element_type decode must not instruct XLA
    to materialise an f32 copy of the whole bf16 cache.  The CPU backend
    upcasts bf16 dot operands itself post-fusion (so optimised-HLO bytes
    tie), hence the discriminator is the *program-level* StableHLO: the
    old body carries full-cache f32 converts, the fixed one none."""
    import math
    import re

    from conftest import cost_bytes
    from repro.core.attention import DenseCache, full_attention_decode

    B, H, S, hd = 1, 2, 4096, 64
    rng = np.random.default_rng(5)
    cache = DenseCache(
        jnp.asarray(rng.standard_normal((B, H, S, hd)), jnp.bfloat16),
        jnp.asarray(rng.standard_normal((B, H, S, hd)), jnp.bfloat16),
        jnp.full((B,), S // 2, jnp.int32))
    q = jnp.asarray(rng.standard_normal((B, 2 * H, hd)), jnp.bfloat16)

    def old_decode(q, cache):               # the pre-fix hoisted-cast body
        Bq, Hq, hdq = q.shape
        qg = q.reshape(Bq, H, Hq // H, hdq)
        s = jnp.einsum("bhgd,bhtd->bhgt", qg.astype(jnp.float32),
                       cache.k.astype(jnp.float32)) / math.sqrt(hdq)
        pos = jnp.arange(cache.k.shape[2])
        ok = pos[None, :] < cache.length[:, None]
        s = jnp.where(ok[:, None, None, :], s, NEG)
        p = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bhgt,bhtd->bhgd", p, cache.v.astype(jnp.float32))
        return out.reshape(Bq, Hq, hdq).astype(q.dtype)

    low_new = jax.jit(full_attention_decode).lower(q, cache)
    low_old = jax.jit(old_decode).lower(q, cache)
    cast = re.compile(
        rf"stablehlo\.convert[^\n]*->\s*tensor<{B}x{H}x{S}x{hd}xf32>")
    assert not cast.search(low_new.as_text()), \
        "fixed decode still upcasts the whole cache"
    assert len(cast.findall(low_old.as_text())) == 2  # k and v upcasts
    c_new = low_new.compile()
    c_old = low_old.compile()
    assert cost_bytes(c_new) <= cost_bytes(c_old), \
        (cost_bytes(c_new), cost_bytes(c_old))
    np.testing.assert_allclose(
        np.asarray(c_new(q, cache), np.float32),
        np.asarray(c_old(q, cache), np.float32), atol=3e-2, rtol=3e-2)
