"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.gather.ops import block_gather_op
from repro.kernels.gather.ref import block_gather_ref
from repro.kernels.kmeans.ops import segmented_kmeans_op
from repro.kernels.kmeans.ref import kmeans_ref
from repro.kernels.wave_attention.kernel import NEG
from repro.kernels.wave_attention.ops import wave_attention_merge
from repro.kernels.wave_attention.ref import wave_attention_ref

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("B,H,G,hd,T,E,softcap", [
    (2, 2, 2, 32, 300, 24, None),
    (1, 4, 8, 64, 1024, 100, 50.0),
    (2, 1, 1, 128, 77, 5, None),
    (1, 2, 4, 256, 513, 64, None),
    (3, 3, 2, 64, 128, 1, 30.0),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_wave_attention_kernel(B, H, G, hd, T, E, softcap, dtype):
    ks = jax.random.split(jax.random.fold_in(KEY, T * E), 8)
    q = jax.random.normal(ks[0], (B, H, G, hd), dtype)
    k = jax.random.normal(ks[1], (B, H, T, hd), dtype)
    v = jax.random.normal(ks[2], (B, H, T, hd), dtype)
    valid = jax.random.bernoulli(ks[3], 0.8, (B, H, T))
    el = jax.random.normal(ks[4], (B, H, G, E)) * 2
    cs = el - jnp.abs(jax.random.normal(ks[5], (B, H, G, E)))
    el = jnp.where(jax.random.bernoulli(ks[6], 0.9, (B, H, G, E)), el, NEG)
    vs = jax.random.normal(ks[7], (B, H, E, hd)) * 3
    out = wave_attention_merge(q, k, v, valid, el, cs, vs, softcap=softcap,
                               interpret=True)
    ref = wave_attention_ref(
        q.reshape(B * H, G, hd), k.reshape(B * H, T, hd),
        v.reshape(B * H, T, hd), valid.reshape(B * H, T).astype(jnp.int32),
        el.reshape(B * H, G, E), cs.reshape(B * H, G, E),
        vs.reshape(B * H, E, hd), softcap=softcap)
    tol = 5e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out).reshape(B * H, G, hd),
                               np.asarray(ref), atol=tol, rtol=tol)


def test_wave_attention_all_invalid_est():
    """Estimation zone fully masked => pure exact attention."""
    B, H, G, hd, T, E = 1, 1, 2, 32, 128, 8
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, G, hd))
    k = jax.random.normal(ks[1], (B, H, T, hd))
    v = jax.random.normal(ks[2], (B, H, T, hd))
    valid = jnp.ones((B, H, T), bool)
    el = jnp.full((B, H, G, E), NEG)
    vs = jnp.zeros((B, H, E, hd))
    out = wave_attention_merge(q, k, v, valid, el, el, vs, interpret=True)
    s = jnp.einsum("bhgd,bhtd->bhgt", q, k) / np.sqrt(hd)
    ref = jnp.einsum("bhgt,bhtd->bhgd", jax.nn.softmax(s, -1), v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


@pytest.mark.parametrize("S,n,d,k,iters", [
    (4, 256, 32, 16, 4), (2, 128, 64, 8, 3), (1, 512, 128, 64, 2),
    (8, 64, 16, 8, 5),
])
def test_kmeans_kernel(S, n, d, k, iters):
    x = jax.random.normal(jax.random.fold_in(KEY, S * n), (S, n, d))
    c0 = x[:, :: max(1, n // k)][:, :k]
    cp, ap = segmented_kmeans_op(x, c0, iters=iters, interpret=True)
    cr, ar = kmeans_ref(x, c0, iters)
    np.testing.assert_allclose(np.asarray(cp), np.asarray(cr), atol=1e-5)
    assert np.mean(np.asarray(ap) == np.asarray(ar)) == 1.0


@pytest.mark.parametrize("B,H,M,cap,hd,r", [
    (2, 2, 64, 16, 32, 8), (1, 1, 128, 32, 64, 13), (4, 2, 32, 8, 128, 32),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gather_kernel(B, H, M, cap, hd, r, dtype):
    ks = jax.random.split(jax.random.fold_in(KEY, M * r), 3)
    kst = jax.random.normal(ks[0], (B, H, M, cap, hd), dtype)
    vst = jax.random.normal(ks[1], (B, H, M, cap, hd), dtype)
    idx = jax.random.randint(ks[2], (B, H, r), 0, M)
    ko, vo = block_gather_op(idx, kst, vst, interpret=True)
    kr, vr = block_gather_ref(idx.reshape(B * H, r),
                              kst.reshape(B * H, M, cap, hd),
                              vst.reshape(B * H, M, cap, hd))
    np.testing.assert_array_equal(np.asarray(ko).reshape(B * H, r, cap, hd),
                                  np.asarray(kr))
    np.testing.assert_array_equal(np.asarray(vo).reshape(B * H, r, cap, hd),
                                  np.asarray(vr))


def test_gather_repeated_indices():
    """Duplicate cluster ids must replicate blocks (cache-hit path)."""
    kst = jnp.arange(4 * 2 * 8, dtype=jnp.float32).reshape(1, 1, 4, 2, 8)
    idx = jnp.asarray([[[2, 2, 0]]])
    ko, _ = block_gather_op(idx, kst, kst, interpret=True)
    np.testing.assert_array_equal(np.asarray(ko[0, 0, 0]),
                                  np.asarray(ko[0, 0, 1]))
    np.testing.assert_array_equal(np.asarray(ko[0, 0, 2]),
                                  np.asarray(kst[0, 0, 0]))


def test_wave_attention_kernel_matches_core_merge():
    """The kernel path (impl='pallas') plugged into the full tripartite
    attention equals the jnp path on identical state."""
    from repro.configs.base import RetroConfig
    from repro.core.attention import wave_attention_decode
    from repro.core.wave_index import max_clusters, prefill_build
    from repro.core.zones import plan_zones

    retro = RetroConfig(avg_cluster=8, cluster_cap=16, prefill_segment=256,
                        update_segment=128, sink=4, local=32, kmeans_iters=3)
    rng = np.random.default_rng(0)
    B, n, H, hd = 2, 640, 2, 32
    k = jnp.asarray(rng.standard_normal((B, n, H, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, n, H, hd)), jnp.float32)
    state = prefill_build(k, v, retro, max_clusters(n, retro, 128),
                          dtype=jnp.float32)
    q = jnp.asarray(rng.standard_normal((B, 2 * H, hd)), jnp.float32)
    plan = plan_zones(n, retro, 128)
    o_jnp = wave_attention_decode(q, state, retro, plan, impl="jnp").out
    o_pal = wave_attention_decode(q, state, retro, plan, impl="pallas").out
    np.testing.assert_allclose(np.asarray(o_jnp), np.asarray(o_pal),
                               atol=1e-5, rtol=1e-5)
