"""Wave-buffer (GPU-CPU block cache) semantics + locality behavior."""
import numpy as np
import pytest

from repro.core.wave_buffer import WaveBuffer


def _mk(n_clusters=64, cache=8, payload=16, policy="lru"):
    host = np.arange(n_clusters * payload, dtype=np.float32).reshape(
        n_clusters, payload)
    return WaveBuffer(host, cache_clusters=cache, policy=policy), host


def test_miss_then_hit():
    buf, host = _mk()
    ids = np.array([3, 7, 9])
    out = buf.assemble(ids)
    np.testing.assert_array_equal(out, host[ids])
    assert buf.stats.misses == 3 and buf.stats.hits == 0
    buf.apply_updates()                   # async admission
    out = buf.assemble(ids)
    np.testing.assert_array_equal(out, host[ids])
    assert buf.stats.hits == 3


def test_no_hit_before_async_update():
    """Deferred update: a repeated miss before apply_updates stays a miss but
    still returns correct data (paper: access decoupled from update) — served
    from the pending set, NOT refetched over the link."""
    buf, host = _mk()
    buf.assemble(np.array([1]))
    per = buf.bytes_per_cluster
    assert buf.stats.bytes_over_link == per
    out = buf.assemble(np.array([1]))     # update not applied yet
    np.testing.assert_array_equal(out, host[[1]])
    assert buf.stats.hits == 0
    assert buf.stats.pending_hits == 1
    assert buf.stats.bytes_over_link == per      # no double fetch
    buf.apply_updates()
    buf.assemble(np.array([1]))
    assert buf.stats.hits == 1


def test_repeat_miss_not_double_counted():
    """Regression: a cluster missed TWICE before apply_updates used to be
    fetched over the link twice and double-counted in bytes_over_link; repeat
    misses are served from the pending set and admitted exactly once."""
    buf, host = _mk(n_clusters=32, cache=8)
    per = buf.bytes_per_cluster
    out = buf.assemble(np.array([3, 5]))
    np.testing.assert_array_equal(out, host[[3, 5]])
    out = buf.assemble(np.array([5, 3, 7]))      # 5, 3 pending; 7 fresh
    np.testing.assert_array_equal(out, host[[5, 3, 7]])
    assert buf.stats.bytes_over_link == 3 * per  # 3, 5, 7 fetched once each
    assert buf.stats.pending_hits == 2
    assert buf.stats.misses == 5                 # still misses, not cache hits
    buf.apply_updates()
    owners = buf.cache_owner[buf.cache_owner >= 0]
    assert len(np.unique(owners)) == len(owners)
    for cid in (3, 5, 7):
        assert buf.table.cache_slot[cid] >= 0
    buf.assemble(np.array([3, 5, 7]))
    assert buf.stats.hits == 3
    # pending set cleared by apply_updates: a new miss refetches over the link
    buf.assemble(np.array([9]))
    assert buf.stats.bytes_over_link == 4 * per


def test_lru_eviction_order():
    buf, host = _mk(n_clusters=32, cache=4)
    for cid in [0, 1, 2, 3]:
        buf.assemble(np.array([cid]))
        buf.apply_updates()
    buf.assemble(np.array([0]))           # touch 0 -> MRU
    buf.assemble(np.array([10]))          # evicts LRU (1)
    buf.apply_updates()
    assert buf.table.cache_slot[1] == -1
    assert buf.table.cache_slot[0] >= 0
    assert buf.table.cache_slot[10] >= 0


def test_correctness_under_any_policy():
    for policy in ("lru", "fifo", "clock"):
        buf, host = _mk(n_clusters=128, cache=16, policy=policy)
        rng = np.random.default_rng(0)
        for _ in range(50):
            ids = rng.choice(128, size=8, replace=False)
            out = buf.assemble(ids)
            np.testing.assert_array_equal(out, host[ids])
            buf.apply_updates()


def test_temporal_locality_hit_ratio():
    """Paper Sec. 4.3: with a cache of ~5-12% and temporally-local requests
    (adjacent decode steps overlap heavily), hit ratio lands high."""
    n = 512
    buf, _ = _mk(n_clusters=n, cache=60)
    rng = np.random.default_rng(1)
    working = rng.choice(n, size=40, replace=False)
    for step in range(200):
        # drift the working set slowly (topic continuity)
        if step % 10 == 0 and step > 0:
            working[rng.integers(0, 40, 4)] = rng.integers(0, n, 4)
        ids = rng.choice(working, size=16, replace=False)
        buf.assemble(ids)
        buf.apply_updates()
    assert buf.stats.hit_ratio > 0.75


@pytest.mark.parametrize("policy", ("lru", "fifo", "clock"))
def test_admit_more_uniques_than_cache(policy):
    """One assemble requesting more unique clusters than the cache holds must
    not crash: admission clips to capacity, owners stay unique, and the
    mapping table stays consistent with cache_owner."""
    buf, host = _mk(n_clusters=64, cache=8, policy=policy)
    ids = np.arange(24)                    # 24 uniques > 8 cache slots
    out = buf.assemble(ids)
    np.testing.assert_array_equal(out, host[ids])
    buf.apply_updates()                    # must not raise
    owners = buf.cache_owner
    live = owners[owners >= 0]
    assert len(np.unique(live)) == len(live)            # no duplicate owner
    for slot, cid in enumerate(owners):
        if cid >= 0:
            assert buf.table.cache_slot[cid] == slot    # table <-> owner
    mapped = buf.table.cache_slot[buf.table.cache_slot >= 0]
    assert len(mapped) == len(live)
    # cached payloads are the right rows; reads stay correct afterwards
    out = buf.assemble(ids)
    np.testing.assert_array_equal(out, host[ids])


def test_admit_clip_preserves_request_order():
    """Regression: np.unique re-sorts ids before the capacity clip, so
    overflow admission used to keep the LOWEST cluster ids instead of the
    first-requested ones. The clip must be first-requested-first-admitted."""
    buf, host = _mk(n_clusters=64, cache=2)
    ids = np.array([50, 9, 30, 3, 40])     # 5 uniques > 2 slots, descending-ish
    out = buf.assemble(ids)
    np.testing.assert_array_equal(out, host[ids])
    buf.apply_updates()
    owners = set(buf.cache_owner[buf.cache_owner >= 0])
    assert owners == {50, 9}, owners       # NOT {3, 9} (id-sorted clip)
    for cid in (50, 9):
        slot = buf.table.cache_slot[cid]
        assert slot >= 0
        np.testing.assert_array_equal(buf.cache[slot], host[cid])
    # duplicates still dedupe to the FIRST occurrence's position
    buf2, host2 = _mk(n_clusters=64, cache=2)
    buf2.assemble(np.array([7, 5, 7, 1]))  # uniques in request order: 7, 5, 1
    buf2.apply_updates()
    assert set(buf2.cache_owner[buf2.cache_owner >= 0]) == {7, 5}


def test_pending_hit_byte_accounting():
    """Regression: a pending hit (repeat miss before apply_updates) used to
    count in NEITHER bytes_from_cache NOR bytes_over_link, and hit_ratio
    treated it as a plain miss — understating the effective hit rate. It now
    lands in bytes_from_pending and effective_hit_ratio includes it."""
    buf, host = _mk(n_clusters=32, cache=8)
    per = buf.bytes_per_cluster
    buf.assemble(np.array([3, 5]))               # 2 fresh misses
    buf.assemble(np.array([5, 3]))               # 2 pending hits
    s = buf.stats
    assert s.pending_hits == 2
    assert s.bytes_over_link == 2 * per          # fetched once each
    assert s.bytes_from_cache == 0
    assert s.bytes_from_pending == 2 * per       # the pending-hit traffic
    assert s.hit_ratio == 0.0                    # strict cache hits only
    assert s.effective_hit_ratio == 0.5          # 2 of 4 lookups never re-cross
    buf.apply_updates()
    buf.assemble(np.array([3, 5]))
    assert buf.stats.hit_ratio == pytest.approx(2 / 6)
    assert buf.stats.effective_hit_ratio == pytest.approx(4 / 6)


@pytest.mark.parametrize("policy", ("lru", "fifo", "clock"))
@pytest.mark.parametrize("cache", (0, 1))
def test_zero_and_one_slot_cache(policy, cache):
    """cache_clusters=0 (tiny int(frac*n) configs round to zero) must degrade
    to an explicit pass-through — correct data, all traffic over the link,
    nothing admitted — and a one-slot cache must actually cache."""
    buf, host = _mk(n_clusters=32, cache=cache, policy=policy)
    assert buf.passthrough == (cache == 0)
    per = buf.bytes_per_cluster
    rng = np.random.default_rng(0)
    for _ in range(10):
        ids = rng.choice(32, size=4, replace=False)
        out = buf.assemble(ids)
        np.testing.assert_array_equal(out, host[ids])
        adm = buf.apply_updates()
        if cache == 0:
            assert adm == []
    s = buf.stats
    if cache == 0:
        assert s.hits == 0 and s.bytes_from_cache == 0
        assert s.bytes_over_link == s.lookups * per  # every lookup crosses
        assert np.all(buf.table.cache_slot == -1)    # nothing ever admitted
    else:
        assert len(buf.cache_owner) == 1
        # repeat the cached cluster: the single slot serves it
        cid = int(buf.cache_owner[0])
        buf.assemble(np.array([cid]))
        assert buf.stats.hits >= 1
    # pending-set semantics hold in pass-through too: no double fetch
    buf2, host2 = _mk(n_clusters=16, cache=cache, policy=policy)
    buf2.assemble(np.array([7]))
    buf2.assemble(np.array([7]))
    assert buf2.stats.bytes_over_link == buf2.bytes_per_cluster
    assert buf2.stats.pending_hits == 1


def test_negative_cache_rejected():
    with pytest.raises(ValueError, match="cache_clusters"):
        _mk(cache=-1)


def test_apply_updates_returns_admissions():
    """The serve engine mirrors host-cache admissions into its device block
    cache: apply_updates returns (slots, ids, payload) triples matching the
    cache content exactly."""
    buf, host = _mk(n_clusters=32, cache=4)
    buf.assemble(np.array([3, 9]))
    adm = buf.apply_updates()
    assert len(adm) == 1
    slots, ids, payload = adm[0]
    np.testing.assert_array_equal(ids, [3, 9])
    np.testing.assert_array_equal(payload, host[[3, 9]])
    np.testing.assert_array_equal(buf.cache[slots], payload)
    for s, c in zip(slots, ids):
        assert buf.table.cache_slot[c] == s
    assert buf.apply_updates() == []             # drained


def test_transfer_accounting():
    buf, host = _mk(n_clusters=16, cache=4, payload=32)
    per = host[0].nbytes
    buf.assemble(np.array([0, 1]))
    assert buf.stats.bytes_over_link == 2 * per
    buf.apply_updates()
    buf.assemble(np.array([0, 1]))
    assert buf.stats.bytes_over_link == 2 * per
    assert buf.stats.bytes_from_cache == 2 * per


# --------------------------------------------------- pending-map lifecycle
def test_pending_map_cleared_by_apply_updates():
    """apply_updates closes the update window: the pending set AND the
    repeat-miss dedup map are both drained, so the next window starts from
    the table alone."""
    buf, host = _mk(n_clusters=16, cache=4)
    buf.translate(np.array([3, 5]))
    assert set(buf._pending_map) == {3, 5}
    buf.apply_updates()
    assert buf._pending_map == {} and buf._pending == []
    # a hit in the new window must not repopulate the pending machinery
    buf.translate(np.array([3]))
    assert buf._pending_map == {} and buf.stats.pending_hits == 0


def test_repeat_miss_after_window_refetches_under_eviction():
    """An id admitted in window 1 then evicted must be re-fetched over the
    link when it misses in window 2 — served from the host store, never from
    a stale pending payload of the previous window."""
    buf, host = _mk(n_clusters=16, cache=2, policy="lru")
    per = buf.bytes_per_cluster
    buf.translate(np.array([0, 1]))
    buf.apply_updates()                       # window 1: 0,1 admitted
    buf.translate(np.array([2, 3]))
    buf.apply_updates()                       # window 2: 0,1 evicted (LRU)
    assert buf.table.cache_slot[0] == -1
    buf.store_rows(0, host[0:1] + 1000.0)     # host store moves on (flush)
    link_before = buf.stats.bytes_over_link
    slot, hit, payload, ok = buf.translate(np.array([0]))
    assert not hit[0]
    np.testing.assert_array_equal(payload[0], host[0])   # fresh, not stale
    assert buf.stats.bytes_over_link == link_before + per  # real re-fetch
    assert buf.stats.pending_hits == 0        # not served from a dead window


def test_pending_hits_scoped_to_window():
    """Repeat misses dedup over the link only within one update window; the
    same id missing across two windows pays the link twice."""
    buf, host = _mk(n_clusters=16, cache=0)   # passthrough: never admitted
    per = buf.bytes_per_cluster
    buf.translate(np.array([7]))
    buf.translate(np.array([7]))              # same window: pending hit
    assert buf.stats.bytes_over_link == per
    assert buf.stats.pending_hits == 1
    buf.apply_updates()
    buf.translate(np.array([7]))              # new window: fetch again
    assert buf.stats.bytes_over_link == 2 * per
    assert buf.stats.pending_hits == 1


def test_byte_counters_consistent_across_windows():
    """Every looked-up cluster is served from exactly one source — link,
    pending set, or device cache — so the three byte counters partition the
    total traffic across any multi-window access sequence."""
    rng = np.random.default_rng(0)
    buf, host = _mk(n_clusters=32, cache=4, policy="lru")
    for step in range(20):
        ids = rng.integers(0, 32, size=rng.integers(1, 6))
        out = buf.assemble(ids)
        np.testing.assert_array_equal(out, host[ids])     # always correct
        if step % 3 == 2:
            buf.apply_updates()
    total = (buf.stats.bytes_over_link + buf.stats.bytes_from_pending
             + buf.stats.bytes_from_cache)
    assert total == buf.stats.lookups * buf.bytes_per_cluster

# --------------------------------------------------------------- retrofault

from repro.core.wave_buffer import (  # noqa: E402
    FatalTransportError, FaultProfile, FaultyTransport, LinkTransport,
    TransientFault)


class _ScriptedTransport(LinkTransport):
    """Deterministic transport: fail the first ``fail_first`` attempts of
    every cluster, charge ``latency_s`` per successful fetch."""

    def __init__(self, fail_first=0, latency_s=0.0):
        self.fail_first = fail_first
        self.latency_s = latency_s
        self.attempts = {}

    def fetch(self, store, cid):
        n = self.attempts.get(cid, 0)
        self.attempts[cid] = n + 1
        if n < self.fail_first:
            raise TransientFault(f"scripted failure {n} for {cid}")
        return store[cid], self.latency_s


def _mk_t(transport, n_clusters=16, cache=4, **kw):
    host = np.arange(n_clusters * 16, dtype=np.float32).reshape(n_clusters, 16)
    return WaveBuffer(host, cache_clusters=cache, transport=transport,
                      **kw), host


def test_translate_rejects_out_of_range_ids():
    """Regression: an out-of-range id from a buggy rank must fail loudly at
    the buffer boundary, not index garbage deep in numpy."""
    buf, _ = _mk(n_clusters=16, cache=4)
    with pytest.raises(ValueError, match="out of range"):
        buf.translate(np.array([3, 16]))
    with pytest.raises(ValueError, match="out of range"):
        buf.translate(np.array([-17]))      # would silently wrap in numpy
    # stats untouched by the rejected call beyond the lookup bump
    assert buf.stats.bytes_over_link == 0


def test_transient_faults_retried_to_success():
    """A miss whose first attempts fail transiently recovers within the retry
    budget: payload correct, faults/retries counted, zero failed fetches."""
    tr = _ScriptedTransport(fail_first=2)
    buf, host = _mk_t(tr, max_retries=2)
    slot, hit, payload, ok = buf.translate(np.array([5]))
    assert ok[0] and not hit[0]
    np.testing.assert_array_equal(payload[0], host[5])
    assert buf.stats.faults == 2 and buf.stats.retries == 2
    assert buf.stats.failed_fetches == 0
    # the recovered miss is pending like any other and admits normally
    buf.apply_updates()
    assert buf.table.cache_slot[5] >= 0


def test_retry_exhaustion_fails_step_then_reconciles():
    """Retries exhausted -> the miss FAILS for this step (ok False, zero
    payload, not pending); a later update window refetches and recovers."""
    tr = _ScriptedTransport(fail_first=3)           # 3 attempts all fail
    buf, host = _mk_t(tr, max_retries=2)
    slot, hit, payload, ok = buf.translate(np.array([5, 7]))
    assert not ok.any()
    assert (payload == 0).all()
    assert 5 not in buf._pending_map and 7 not in buf._pending_map
    assert buf.stats.failed_fetches == 2
    buf.apply_updates()
    # next window: cluster 5's attempt counter is past fail_first -> recovers
    slot, hit, payload, ok = buf.translate(np.array([5]))
    assert ok[0]
    np.testing.assert_array_equal(payload[0], host[5])


def test_deadline_budget_fails_slow_fetches():
    """Per-call virtual deadline: fetch latency over budget -> failed fetch;
    ample budget -> same fetch succeeds. No real time involved."""
    buf, host = _mk_t(_ScriptedTransport(latency_s=0.2))
    slot, hit, payload, ok = buf.translate(np.array([3]), deadline_s=0.1)
    assert not ok[0] and buf.stats.failed_fetches == 1
    slot, hit, payload, ok = buf.translate(np.array([3]), deadline_s=0.5)
    assert ok[0]
    np.testing.assert_array_equal(payload[0], host[3])


def test_deadline_budget_shared_across_misses():
    """The deadline budget is shared by all misses of one translate call:
    with 0.2s per fetch and a 0.5s budget only the first two fit."""
    buf, host = _mk_t(_ScriptedTransport(latency_s=0.2))
    slot, hit, payload, ok = buf.translate(np.array([0, 1, 2, 3]),
                                           deadline_s=0.5)
    assert ok.tolist() == [True, True, False, False]
    assert buf.stats.failed_fetches == 2


def test_corrupt_payload_caught_by_checksum():
    """In-flight corruption is caught by the per-row crc32 and retried; with
    corruption on every attempt the fetch fails cleanly (never serves bad
    bytes). The host store itself is never damaged."""
    tr = FaultyTransport(FaultProfile(corrupt=1.0, seed=0))
    buf, host = _mk_t(tr, max_retries=1)
    before = host.copy()
    slot, hit, payload, ok = buf.translate(np.array([2]))
    assert not ok[0] and (payload[0] == 0).all()
    assert buf.stats.corrupt_fetches == 2          # initial + 1 retry
    assert buf.stats.failed_fetches == 1
    np.testing.assert_array_equal(host, before)    # store undamaged


def test_store_rows_refreshes_checksums():
    """store_rows (the flush path) keeps fetches verifiable; a raw slice
    write would leave a stale crc and read back as corruption."""
    buf, host = _mk_t(LinkTransport())
    buf.store_rows(4, host[4:6] * 2.0 + 1.0)
    slot, hit, payload, ok = buf.translate(np.array([4, 5]))
    assert ok.all()
    np.testing.assert_array_equal(payload, host[4:6])
    # now model the bug the docstring warns about: stale crc reads as corrupt
    buf.apply_updates()
    buf.kv_host[6] += 1.0                          # bypasses store_rows
    slot, hit, payload, ok = buf.translate(np.array([6]))
    assert not ok[0] and buf.stats.corrupt_fetches > 0


def test_fatal_transport_error_propagates():
    tr = FaultyTransport(FaultProfile(fatal=1.0, seed=0))
    buf, _ = _mk_t(tr)
    with pytest.raises(FatalTransportError):
        buf.translate(np.array([1]))


def test_fault_schedule_is_seed_deterministic():
    """Two buffers driven identically with same-seed FaultyTransports observe
    the same fault schedule (same stats, same ok masks)."""
    profile = FaultProfile(transient=0.4, corrupt=0.1, spike=0.3,
                           latency_s=0.01, seed=7)
    runs = []
    for _ in range(2):
        buf, _ = _mk_t(FaultyTransport(profile), n_clusters=32, cache=8,
                       max_retries=2)
        oks = []
        rng = np.random.default_rng(0)
        for _ in range(12):
            ids = rng.integers(0, 32, size=4)
            *_, ok = buf.translate(ids, deadline_s=0.05)
            oks.append(ok.copy())
            buf.apply_updates()
        runs.append((oks, vars(buf.stats).copy()))
    for a, b in zip(runs[0][0], runs[1][0]):
        np.testing.assert_array_equal(a, b)
    assert runs[0][1] == runs[1][1]


def test_faulty_transport_default_rates_are_clean():
    """A zero-rate FaultyTransport is byte-identical to the production
    transport (the rate==0 guards never consume rng draws)."""
    buf, host = _mk_t(FaultyTransport(FaultProfile()))
    out = buf.assemble(np.array([1, 2, 3]))
    np.testing.assert_array_equal(out, host[[1, 2, 3]])
    assert buf.stats.faults == 0 and buf.stats.failed_fetches == 0
