"""Wave-buffer (GPU-CPU block cache) semantics + locality behavior."""
import numpy as np
import pytest

from repro.core.wave_buffer import WaveBuffer


def _mk(n_clusters=64, cache=8, payload=16, policy="lru"):
    host = np.arange(n_clusters * payload, dtype=np.float32).reshape(
        n_clusters, payload)
    return WaveBuffer(host, cache_clusters=cache, policy=policy), host


def test_miss_then_hit():
    buf, host = _mk()
    ids = np.array([3, 7, 9])
    out = buf.assemble(ids)
    np.testing.assert_array_equal(out, host[ids])
    assert buf.stats.misses == 3 and buf.stats.hits == 0
    buf.apply_updates()                   # async admission
    out = buf.assemble(ids)
    np.testing.assert_array_equal(out, host[ids])
    assert buf.stats.hits == 3


def test_no_hit_before_async_update():
    """Deferred update: a repeated miss before apply_updates stays a miss but
    still returns correct data (paper: access decoupled from update) — served
    from the pending set, NOT refetched over the link."""
    buf, host = _mk()
    buf.assemble(np.array([1]))
    per = buf.bytes_per_cluster
    assert buf.stats.bytes_over_link == per
    out = buf.assemble(np.array([1]))     # update not applied yet
    np.testing.assert_array_equal(out, host[[1]])
    assert buf.stats.hits == 0
    assert buf.stats.pending_hits == 1
    assert buf.stats.bytes_over_link == per      # no double fetch
    buf.apply_updates()
    buf.assemble(np.array([1]))
    assert buf.stats.hits == 1


def test_repeat_miss_not_double_counted():
    """Regression: a cluster missed TWICE before apply_updates used to be
    fetched over the link twice and double-counted in bytes_over_link; repeat
    misses are served from the pending set and admitted exactly once."""
    buf, host = _mk(n_clusters=32, cache=8)
    per = buf.bytes_per_cluster
    out = buf.assemble(np.array([3, 5]))
    np.testing.assert_array_equal(out, host[[3, 5]])
    out = buf.assemble(np.array([5, 3, 7]))      # 5, 3 pending; 7 fresh
    np.testing.assert_array_equal(out, host[[5, 3, 7]])
    assert buf.stats.bytes_over_link == 3 * per  # 3, 5, 7 fetched once each
    assert buf.stats.pending_hits == 2
    assert buf.stats.misses == 5                 # still misses, not cache hits
    buf.apply_updates()
    owners = buf.cache_owner[buf.cache_owner >= 0]
    assert len(np.unique(owners)) == len(owners)
    for cid in (3, 5, 7):
        assert buf.table.cache_slot[cid] >= 0
    buf.assemble(np.array([3, 5, 7]))
    assert buf.stats.hits == 3
    # pending set cleared by apply_updates: a new miss refetches over the link
    buf.assemble(np.array([9]))
    assert buf.stats.bytes_over_link == 4 * per


def test_lru_eviction_order():
    buf, host = _mk(n_clusters=32, cache=4)
    for cid in [0, 1, 2, 3]:
        buf.assemble(np.array([cid]))
        buf.apply_updates()
    buf.assemble(np.array([0]))           # touch 0 -> MRU
    buf.assemble(np.array([10]))          # evicts LRU (1)
    buf.apply_updates()
    assert buf.table.cache_slot[1] == -1
    assert buf.table.cache_slot[0] >= 0
    assert buf.table.cache_slot[10] >= 0


def test_correctness_under_any_policy():
    for policy in ("lru", "fifo", "clock"):
        buf, host = _mk(n_clusters=128, cache=16, policy=policy)
        rng = np.random.default_rng(0)
        for _ in range(50):
            ids = rng.choice(128, size=8, replace=False)
            out = buf.assemble(ids)
            np.testing.assert_array_equal(out, host[ids])
            buf.apply_updates()


def test_temporal_locality_hit_ratio():
    """Paper Sec. 4.3: with a cache of ~5-12% and temporally-local requests
    (adjacent decode steps overlap heavily), hit ratio lands high."""
    n = 512
    buf, _ = _mk(n_clusters=n, cache=60)
    rng = np.random.default_rng(1)
    working = rng.choice(n, size=40, replace=False)
    for step in range(200):
        # drift the working set slowly (topic continuity)
        if step % 10 == 0 and step > 0:
            working[rng.integers(0, 40, 4)] = rng.integers(0, n, 4)
        ids = rng.choice(working, size=16, replace=False)
        buf.assemble(ids)
        buf.apply_updates()
    assert buf.stats.hit_ratio > 0.75


@pytest.mark.parametrize("policy", ("lru", "fifo", "clock"))
def test_admit_more_uniques_than_cache(policy):
    """One assemble requesting more unique clusters than the cache holds must
    not crash: admission clips to capacity, owners stay unique, and the
    mapping table stays consistent with cache_owner."""
    buf, host = _mk(n_clusters=64, cache=8, policy=policy)
    ids = np.arange(24)                    # 24 uniques > 8 cache slots
    out = buf.assemble(ids)
    np.testing.assert_array_equal(out, host[ids])
    buf.apply_updates()                    # must not raise
    owners = buf.cache_owner
    live = owners[owners >= 0]
    assert len(np.unique(live)) == len(live)            # no duplicate owner
    for slot, cid in enumerate(owners):
        if cid >= 0:
            assert buf.table.cache_slot[cid] == slot    # table <-> owner
    mapped = buf.table.cache_slot[buf.table.cache_slot >= 0]
    assert len(mapped) == len(live)
    # cached payloads are the right rows; reads stay correct afterwards
    out = buf.assemble(ids)
    np.testing.assert_array_equal(out, host[ids])


def test_admit_clip_preserves_request_order():
    """Regression: np.unique re-sorts ids before the capacity clip, so
    overflow admission used to keep the LOWEST cluster ids instead of the
    first-requested ones. The clip must be first-requested-first-admitted."""
    buf, host = _mk(n_clusters=64, cache=2)
    ids = np.array([50, 9, 30, 3, 40])     # 5 uniques > 2 slots, descending-ish
    out = buf.assemble(ids)
    np.testing.assert_array_equal(out, host[ids])
    buf.apply_updates()
    owners = set(buf.cache_owner[buf.cache_owner >= 0])
    assert owners == {50, 9}, owners       # NOT {3, 9} (id-sorted clip)
    for cid in (50, 9):
        slot = buf.table.cache_slot[cid]
        assert slot >= 0
        np.testing.assert_array_equal(buf.cache[slot], host[cid])
    # duplicates still dedupe to the FIRST occurrence's position
    buf2, host2 = _mk(n_clusters=64, cache=2)
    buf2.assemble(np.array([7, 5, 7, 1]))  # uniques in request order: 7, 5, 1
    buf2.apply_updates()
    assert set(buf2.cache_owner[buf2.cache_owner >= 0]) == {7, 5}


def test_transfer_accounting():
    buf, host = _mk(n_clusters=16, cache=4, payload=32)
    per = host[0].nbytes
    buf.assemble(np.array([0, 1]))
    assert buf.stats.bytes_over_link == 2 * per
    buf.apply_updates()
    buf.assemble(np.array([0, 1]))
    assert buf.stats.bytes_over_link == 2 * per
    assert buf.stats.bytes_from_cache == 2 * per
