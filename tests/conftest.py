import os
import sys

# Tests run on the single real CPU device — the 512-device dry-run sets its
# XLA_FLAGS itself (and only in its own process; see launch/dryrun.py).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: long-running test (deselect with -m 'not slow')")
    config.addinivalue_line(
        "markers", "chaos: seeded fault-injection soak (scheduled CI lane; "
                   "deselect with -m 'not chaos')")


def cost_bytes(compiled) -> float:
    """XLA 'bytes accessed' of a ``jit(...).lower(...).compile()`` result
    (jax returns a dict, or a list of per-device dicts on some versions)."""
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    return float(ca.get("bytes accessed", 0.0))
