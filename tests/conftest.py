import os
import sys

# Tests run on the single real CPU device — the 512-device dry-run sets its
# XLA_FLAGS itself (and only in its own process; see launch/dryrun.py).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: long-running test (deselect with -m 'not slow')")
