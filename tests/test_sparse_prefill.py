"""Block-sparse prefill (paper Fig. 12 compatibility path)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sparse_prefill import block_sparse_attention
from repro.data.pipeline import clustered_keys
from repro.models.layers import flash_attention_jnp

KEY = jax.random.PRNGKey(0)


def _rand(B=1, T=512, Hq=4, Hkv=2, hd=32, seed=0):
    ks = jax.random.split(jax.random.fold_in(KEY, seed), 3)
    q = jax.random.normal(ks[0], (B, T, Hq, hd))
    k = jax.random.normal(ks[1], (B, T, Hkv, hd))
    v = jax.random.normal(ks[2], (B, T, Hkv, hd))
    return q, k, v


def test_exact_when_all_blocks_selected():
    q, k, v = _rand(T=512)
    out = block_sparse_attention(q, k, v, block=128, topk_blocks=4,
                                 sink_blocks=0, local_blocks=0)
    ref = flash_attention_jnp(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5,
                               rtol=1e-4)


def test_exact_when_all_blocks_selected_windowed():
    q, k, v = _rand(T=512, seed=1)
    w = jnp.asarray(200.0)
    out = block_sparse_attention(q, k, v, block=128, topk_blocks=4,
                                 sink_blocks=0, local_blocks=0, window=w)
    ref = flash_attention_jnp(q, k, v, causal=True, window=w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5,
                               rtol=1e-4)


def test_softcap_exactness():
    q, k, v = _rand(T=256, seed=2)
    out = block_sparse_attention(q, k, v, block=128, topk_blocks=2,
                                 sink_blocks=0, local_blocks=0, softcap=30.0)
    ref = flash_attention_jnp(q, k, v, causal=True, softcap=30.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5,
                               rtol=1e-4)


def test_sparse_close_on_structured_keys():
    """On scattered-hot-span keys, top-k block selection recovers nearly the
    dense output at ~25% of the blocks."""
    n, hd = 2048, 32
    keys, qv, hot = clustered_keys(n, hd, n_hot=4, seed=0)
    rng = np.random.default_rng(1)
    vals = rng.standard_normal((n, hd)).astype(np.float32)
    k = jnp.asarray(keys)[None, :, None, :]
    v = jnp.asarray(vals)[None, :, None, :]
    q = jnp.broadcast_to(jnp.asarray(qv), (1, n, 1, hd)) * 1.0
    dense = flash_attention_jnp(q, k, v, causal=True)
    sparse = block_sparse_attention(q, k, v, block=128, topk_blocks=6,
                                    sink_blocks=1, local_blocks=2)
    rand = block_sparse_attention(q, k, v, block=128, topk_blocks=0,
                                  sink_blocks=1, local_blocks=2)
    # compare at the last query position (sees the full context)
    d = np.asarray(dense)[0, -1, 0]
    s = np.asarray(sparse)[0, -1, 0]
    r = np.asarray(rand)[0, -1, 0]
    rel = np.linalg.norm(s - d) / np.linalg.norm(d)
    rel_stream = np.linalg.norm(r - d) / np.linalg.norm(d)
    # top-k selection must beat the streaming-llm (sink+local only) floor
    assert rel < 0.6 * rel_stream + 1e-6, (rel, rel_stream)
    assert rel < 0.35, rel


def test_prefill_integration_sparse_plus_wave_index():
    """Sparse prefill composes with the wave index (paper Sec. 5.2)."""
    from repro.configs.base import AttnConfig, InputShape, ModelConfig
    from repro.configs.registry import SMOKE_RETRO, materialize_batch
    from repro.core.zones import plan_zones
    from repro.models import model as M

    cfg = ModelConfig(
        arch_id="sparse-pre", family="dense", n_layers=2, d_model=64,
        d_ff=128, vocab=256,
        attn=AttnConfig(n_heads=4, n_kv_heads=2, head_dim=16),
        dtype="float32", retro=SMOKE_RETRO, sparse_prefill_blocks=2)
    S = 512
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batch = materialize_batch(cfg, InputShape("p", S, 2, "prefill"))
    plan = plan_zones(S, cfg.retro, 256)
    logits, state = M.apply_prefill(params, cfg, batch, runtime="retro",
                                    plan=plan, gen_headroom=256)
    assert np.isfinite(np.asarray(logits)).all()
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    logits2, _ = M.apply_decode(params, cfg, state, tok, runtime="retro",
                                plan=plan)
    assert np.isfinite(np.asarray(logits2)).all()
    # dense-prefill reference: logits should be in the same ballpark
    cfg_d = cfg.replace(sparse_prefill_blocks=0)
    logits_d, _ = M.apply_prefill(params, cfg_d, batch, runtime="retro",
                                  plan=plan, gen_headroom=256)
    corr = np.corrcoef(np.asarray(logits).ravel(),
                       np.asarray(logits_d).ravel())[0, 1]
    assert corr > 0.9, corr
