"""Training substrate: optimizer properties, loss descent, checkpointing."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import AttnConfig, ModelConfig
from repro.configs.registry import SMOKE_RETRO
from repro.data.pipeline import lm_batches, needle_prompt, shard_batch
from repro.training import checkpoint as ckpt
from repro.training.optimizer import (AdamWConfig, adamw_update, cosine_lr,
                                      global_norm, init_adamw)
from repro.training.train_loop import init_train_state, train

TINY = ModelConfig(
    arch_id="tiny", family="dense", n_layers=2, d_model=64, d_ff=128,
    vocab=256, attn=AttnConfig(n_heads=4, n_kv_heads=2, head_dim=16),
    dtype="float32", retro=SMOKE_RETRO)


def test_loss_decreases():
    data = lm_batches(TINY, batch=8, seq=64, seed=0)
    _, hist = train(TINY, AdamWConfig(lr=3e-3, warmup_steps=5,
                                      total_steps=60), data, steps=60,
                    log_every=5)
    first, last = hist[0]["loss"], hist[-1]["loss"]
    assert last < first - 0.5, (first, last)


def test_cosine_schedule():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_frac=0.1)
    assert float(cosine_lr(cfg, jnp.asarray(5))) == pytest.approx(0.5)
    assert float(cosine_lr(cfg, jnp.asarray(10))) == pytest.approx(1.0, abs=0.01)
    assert float(cosine_lr(cfg, jnp.asarray(100))) == pytest.approx(0.1, abs=0.01)


def test_grad_clip_bounds_update():
    params = {"w": jnp.ones((4, 4))}
    grads = {"w": jnp.full((4, 4), 100.0)}
    st = init_adamw(params)
    cfg = AdamWConfig(lr=1.0, clip_norm=1.0, warmup_steps=0, total_steps=1,
                      weight_decay=0.0)
    _, _, m = adamw_update(cfg, grads, st, params)
    assert float(m["grad_norm"]) == pytest.approx(400.0)
    clipped, _ = jax.tree.flatten(grads)
    assert float(global_norm({"w": grads["w"] / 400.0})) <= 1.0 + 1e-5


def test_checkpoint_roundtrip():
    state = init_train_state(TINY, jax.random.PRNGKey(0))
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, state, step=7)
        restored, step = ckpt.restore(d, state)
        assert step == 7
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_shape_mismatch_rejected():
    state = {"w": jnp.ones((2, 2))}
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, state)
        with pytest.raises(AssertionError):
            ckpt.restore(d, {"w": jnp.ones((3, 3))})


def test_data_determinism_and_sharding():
    b1 = next(lm_batches(TINY, 8, 32, seed=42))
    b2 = next(lm_batches(TINY, 8, 32, seed=42))
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["targets"][:, :-1])
    s0 = shard_batch(b1, n_hosts=2, host_id=0)
    s1 = shard_batch(b1, n_hosts=2, host_id=1)
    assert s0["tokens"].shape[0] == 4
    np.testing.assert_array_equal(
        np.concatenate([s0["tokens"], s1["tokens"]]), b1["tokens"])


def test_needle_prompt_structure():
    toks, pos = needle_prompt(vocab=1024, seq=2048, n_needles=4, seed=0)
    assert toks.shape == (2048,)
    for i, p in enumerate(pos):
        assert (toks[p:p + 8] == 1024 - 1 - i).all()
