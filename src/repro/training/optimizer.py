"""AdamW + cosine schedule + global-norm clipping, pure-JAX pytrees.

No optax in this environment — implemented directly. Optimizer state is a
pytree matching the params structure, so it shards under pjit with the same
rules as the parameters (ZeRO-style sharding falls out of the weight specs).
"""
from __future__ import annotations

import math
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


class AdamWConfig(NamedTuple):
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def init_adamw(params) -> AdamWState:
    zeros = lambda p: jax.tree.map(lambda a: jnp.zeros_like(a, jnp.float32), p)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros(params),
                      nu=zeros(params))


def cosine_lr(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = cfg.lr * step / max(1, cfg.warmup_steps)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    cos = cfg.min_lr_frac * cfg.lr + (1 - cfg.min_lr_frac) * cfg.lr \
        * 0.5 * (1.0 + jnp.cos(math.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: g * scale, grads), norm


def adamw_update(cfg: AdamWConfig, grads, state: AdamWState, params):
    """-> (new_params, new_state, metrics)."""
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state.step + 1
    lr = cosine_lr(cfg, step)
    b1, b2 = cfg.b1, cfg.b2

    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, grads)
    nu = jax.tree.map(lambda n, g: b2 * n + (1 - b2) * jnp.square(g),
                      state.nu, grads)
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, m, n):
        mhat = m / bc1
        nhat = n / bc2
        delta = mhat / (jnp.sqrt(nhat) + cfg.eps) + cfg.weight_decay \
            * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    return new_params, AdamWState(step=step, mu=mu, nu=nu), \
        {"lr": lr, "grad_norm": gnorm}
