"""Pytree checkpointing (msgpack + raw npy payloads, no orbax)."""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree) -> Tuple[Dict[str, np.ndarray], Any]:
    leaves, treedef = jax.tree.flatten(tree)
    return {f"leaf_{i}": np.asarray(l) for i, l in enumerate(leaves)}, treedef


def save(path: str, tree, step: int = 0, meta: Dict | None = None):
    os.makedirs(path, exist_ok=True)
    flat, treedef = _flatten(tree)
    np.savez(os.path.join(path, "arrays.npz"), **flat)
    with open(os.path.join(path, "meta.json"), "w") as f:
        json.dump({"step": step, "treedef": str(treedef),
                   "n_leaves": len(flat), "meta": meta or {}}, f)


def restore(path: str, like) -> Tuple[Any, int]:
    """Restore into the structure of ``like`` (validates leaf count/shapes)."""
    with np.load(os.path.join(path, "arrays.npz")) as z:
        arrays = [z[f"leaf_{i}"] for i in range(len(z.files))]
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    leaves, treedef = jax.tree.flatten(like)
    assert len(leaves) == len(arrays), \
        f"checkpoint has {len(arrays)} leaves, expected {len(leaves)}"
    for a, l in zip(arrays, leaves):
        assert a.shape == l.shape, (a.shape, l.shape)
    restored = jax.tree.unflatten(
        treedef, [jnp.asarray(a, dtype=l.dtype) for a, l in zip(arrays, leaves)])
    return restored, meta["step"]
