"""Train-step builder + host training loop."""
from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Tuple

import jax

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.training.optimizer import (AdamWConfig, AdamWState, adamw_update,
                                      init_adamw)


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig
                    ) -> Callable[[TrainState, Dict], Tuple[TrainState, Dict]]:
    """Returns train_step(state, batch) -> (state, metrics). This is the
    function dryrun.py lowers for the train_4k shape."""

    def train_step(state: TrainState, batch):
        loss, grads = jax.value_and_grad(
            lambda p: M.lm_loss(p, cfg, batch))(state.params)
        params, opt, om = adamw_update(opt_cfg, grads, state.opt, state.params)
        metrics = {"loss": loss, **om}
        return TrainState(params=params, opt=opt), metrics

    return train_step


def init_train_state(cfg: ModelConfig, key) -> TrainState:
    params = M.init_params(cfg, key)
    return TrainState(params=params, opt=init_adamw(params))


def train(cfg: ModelConfig, opt_cfg: AdamWConfig, data_iter, steps: int,
          key=None, log_every: int = 10, callback=None):
    """Single-host training loop (examples/tests scale)."""
    key = key if key is not None else jax.random.PRNGKey(0)
    state = init_train_state(cfg, key)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg))
    history = []
    for i in range(steps):
        batch = next(data_iter)
        state, metrics = step_fn(state, batch)
        if i % log_every == 0 or i == steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            history.append({"step": i, **m})
            if callback:
                callback(i, m)
    return state, history
