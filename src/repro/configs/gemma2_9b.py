"""gemma2-9b — local/global alternating, logit softcap [arXiv:2408.00118]."""
from repro.configs.base import AttnConfig, ModelConfig

CONFIG = ModelConfig(
    arch_id="gemma2-9b", family="dense",
    n_layers=42, d_model=3584, d_ff=14336, vocab=256000,
    attn=AttnConfig(n_heads=16, n_kv_heads=8, head_dim=256,
                    softcap=50.0, sliding_window=4096, pattern=("l", "g")),
    act="gelu",
    source="arXiv:2408.00118 (Gemma2-9B: 42L d=3584 16H GQA kv=8 d_ff=14336 "
           "vocab=256000, alternating SWA+global, attn softcap 50)",
)


def reduced():
    from repro.configs.registry import SMOKE_RETRO
    return CONFIG.replace(
        n_layers=2, d_model=128, d_ff=256, vocab=512,
        attn=AttnConfig(n_heads=4, n_kv_heads=2, head_dim=32, softcap=50.0,
                        sliding_window=128, pattern=("l", "g")),
        dtype="float32", retro=SMOKE_RETRO)
