"""gemma3-1b — 5:1 local:global interleave, 262k vocab [hf:google/gemma-3-1b-pt]."""
from repro.configs.base import AttnConfig, ModelConfig

CONFIG = ModelConfig(
    arch_id="gemma3-1b", family="dense",
    n_layers=26, d_model=1152, d_ff=6912, vocab=262144,
    attn=AttnConfig(n_heads=4, n_kv_heads=1, head_dim=256,
                    rope_theta=1_000_000.0, sliding_window=512,
                    pattern=("l", "l", "l", "l", "l", "g")),
    act="gelu",
    source="hf:google/gemma-3-1b-pt (26L d=1152 4H GQA kv=1 d_ff=6912 "
           "vocab=262144, 5:1 local:global, 128k ctx)",
)


def reduced():
    from repro.configs.registry import SMOKE_RETRO
    return CONFIG.replace(
        n_layers=2, d_model=128, d_ff=256, vocab=512,
        attn=AttnConfig(n_heads=4, n_kv_heads=1, head_dim=32,
                        sliding_window=128, pattern=("l", "g")),
        dtype="float32", retro=SMOKE_RETRO)
