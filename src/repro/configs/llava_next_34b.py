"""llava-next-34b — anyres-tiling VLM backbone [hf:llava-hf/llava-v1.6].

Vision encoder + projector are STUBS per the assignment: input_specs()
supplies precomputed patch embeddings (anyres ~5 tiles x 576 patches).
"""
from repro.configs.base import AttnConfig, ModelConfig

CONFIG = ModelConfig(
    arch_id="llava-next-34b", family="vlm",
    n_layers=60, d_model=7168, d_ff=20480, vocab=64000,
    attn=AttnConfig(n_heads=56, n_kv_heads=8, head_dim=128,
                    rope_theta=5_000_000.0),
    num_patch_tokens=2880,
    tie_embeddings=False,
    source="hf:llava-hf/llava-v1.6 (34B backbone: 60L d=7168 56H GQA kv=8 "
           "d_ff=20480 vocab=64000, anyres tiling)",
)


def reduced():
    from repro.configs.registry import SMOKE_RETRO
    return CONFIG.replace(
        n_layers=2, d_model=128, d_ff=256, vocab=512, num_patch_tokens=64,
        attn=AttnConfig(n_heads=4, n_kv_heads=2, head_dim=32),
        dtype="float32", retro=SMOKE_RETRO)
