"""minitron-8b — width-pruned Nemotron-4 [arXiv:2407.14679]."""
from repro.configs.base import AttnConfig, ModelConfig

CONFIG = ModelConfig(
    arch_id="minitron-8b", family="dense",
    n_layers=32, d_model=4096, d_ff=16384, vocab=256000,
    attn=AttnConfig(n_heads=32, n_kv_heads=8, head_dim=128),
    tie_embeddings=False,
    source="arXiv:2407.14679 (Minitron-8B: 32L d=4096 32H GQA kv=8 "
           "d_ff=16384 vocab=256000)",
)


def reduced():
    from repro.configs.registry import SMOKE_RETRO
    return CONFIG.replace(
        n_layers=2, d_model=128, d_ff=256, vocab=512,
        attn=AttnConfig(n_heads=4, n_kv_heads=2, head_dim=32),
        dtype="float32", retro=SMOKE_RETRO)
