"""Config system: architecture + RetroInfer knobs.

Every assigned architecture gets a module in ``repro/configs/<id>.py`` exposing
``CONFIG`` (the exact published geometry, cited) and ``reduced()`` (a tiny
same-family variant for CPU smoke tests).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class AttnConfig:
    n_heads: int
    n_kv_heads: int
    head_dim: int
    rope_theta: float = 10_000.0
    softcap: Optional[float] = None          # gemma2 logit softcapping
    sliding_window: Optional[int] = None     # window width for "local" layers
    # layer pattern, cycled over depth: "g" global, "l" local(sliding window)
    pattern: Tuple[str, ...] = ("g",)


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_expert: int                            # per-expert FFN hidden size
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    aux_loss_weight: float = 0.01


@dataclass(frozen=True)
class SSMConfig:
    kind: str                                # "rwkv6" | "mamba2"
    state_size: int = 64                     # mamba2 N / rwkv head_dim
    head_dim: int = 64
    expand: int = 2                          # mamba2 inner expansion
    conv_kernel: int = 4
    dt_rank: int = 0                         # 0 => heads-many scalar dts (mamba2)


@dataclass(frozen=True)
class RetroConfig:
    """Wave-index geometry (paper Sec. 4.2, 5.1 defaults)."""
    avg_cluster: int = 16                    # 1 centroid per 16 tokens
    cluster_cap: int = 32                    # fixed capacity (2x avg), see DESIGN
    prefill_segment: int = 8192              # segmented clustering segment
    update_segment: int = 1024               # decode-time flush granularity
    sink: int = 4                            # steady zone: initial tokens
    local: int = 64                          # steady zone: local window
    retrieval_frac: float = 0.018            # retrieval zone budget (1.8%)
    estimation_frac: float = 0.232           # estimation zone budget (23.2%)
    kmeans_iters: int = 10
    centering: bool = True                   # MagicPIG-style mean centering
    distributed_retrieval: bool = False      # beyond-paper: local top-k + LSE psum
    serial_prefill_segments: bool = False    # lax.map segments (peak-mem iter)
    # decode-attention impl: "jnp" (reference execution-buffer path) or
    # "fused" (gather-free paged Pallas kernel, Sec. 4.6; interpret-mode on
    # CPU). Engines/launchers may override per run.
    attn_impl: str = "jnp"
    # host-offload wave buffer (paper Sec. 4.3): decode-time cluster retrieval
    # goes through a device block cache backed by host-resident KV stores;
    # cache placement is accuracy-agnostic (token-for-token identical to the
    # direct-store path). Engines/launchers may override per run.
    offload: bool = False
    # device block-cache size: ``cache_clusters`` absolute slots, or (when 0)
    # ``cache_frac`` of the static cluster-store size — always clamped >= 1.
    cache_clusters: int = 0
    cache_frac: float = 0.2
    cache_policy: str = "lru"

    def n_clusters(self, seq_len: int) -> int:
        return max(1, seq_len // self.avg_cluster)

    def r_clusters(self, seq_len: int) -> int:
        m = self.n_clusters(seq_len)
        return max(1, int(round(m * self.retrieval_frac)))

    def e_clusters(self, seq_len: int) -> int:
        m = self.n_clusters(seq_len)
        return max(1, int(round(m * self.estimation_frac)))


@dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str                              # dense|moe|ssm|hybrid|vlm|audio
    n_layers: int
    d_model: int
    d_ff: int
    vocab: int
    attn: Optional[AttnConfig] = None
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid (zamba2): one shared attention block applied every k SSM blocks
    shared_attn_every: int = 0
    # enc-dec (whisper)
    encoder_layers: int = 0
    encoder_frames: int = 1500
    # vlm: number of stub patch-embedding tokens prepended to the text prompt
    num_patch_tokens: int = 0
    act: str = "silu"                        # "silu" (llama-like) | "gelu" (gemma)
    # MoE dispatch groups (aligned with the 'data' mesh axis): sorts/packs
    # stay shard-local. 1 = paper-agnostic global dispatch (§Perf baseline).
    moe_dispatch_groups: int = 1
    # Block-sparse prefill (paper Fig. 12 compatibility): top-k key blocks per
    # query block during prefill. 0 = dense (flash) prefill.
    sparse_prefill_blocks: int = 0
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"                  # param/compute dtype for lowering
    retro: RetroConfig = field(default_factory=RetroConfig)
    source: str = ""                         # citation

    # ---- derived ----
    @property
    def n_heads(self) -> int:
        return self.attn.n_heads if self.attn else 0

    @property
    def n_kv_heads(self) -> int:
        return self.attn.n_kv_heads if self.attn else 0

    @property
    def head_dim(self) -> int:
        return self.attn.head_dim if self.attn else 0

    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer attention kind ('g'/'l') cycled from the pattern."""
        if self.attn is None:
            return tuple("s" for _ in range(self.n_layers))
        p = self.attn.pattern
        return tuple(p[i % len(p)] for i in range(self.n_layers))

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        d, L = self.d_model, self.n_layers
        n = self.vocab * d
        if not self.tie_embeddings:
            n += self.vocab * d
        per_layer = 0
        if self.attn is not None:
            a = self.attn
            qkv = d * a.n_heads * a.head_dim + 2 * d * a.n_kv_heads * a.head_dim
            per_layer += qkv + a.n_heads * a.head_dim * d
        if self.moe is not None:
            per_layer += self.moe.num_experts * 3 * d * self.moe.d_expert
            per_layer += d * self.moe.num_experts  # router
        elif self.ssm is not None and self.attn is None:
            per_layer += 8 * d * d  # rough ssm block size
        else:
            per_layer += 3 * d * self.d_ff
        n += per_layer * L
        if self.shared_attn_every and self.attn is not None:
            a = self.attn
            n += (d * a.n_heads * a.head_dim + 2 * d * a.n_kv_heads * a.head_dim
                  + a.n_heads * a.head_dim * d + 3 * d * self.d_ff)
        return n

    def active_param_count(self) -> int:
        """Active parameters per token (MoE: top_k experts only)."""
        if self.moe is None:
            return self.param_count()
        full = self.param_count()
        moe_all = self.n_layers * self.moe.num_experts * 3 * self.d_model * self.moe.d_expert
        moe_active = self.n_layers * self.moe.top_k * 3 * self.d_model * self.moe.d_expert
        return full - moe_all + moe_active


# Input-shape suite assigned to this paper.
@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                                # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}
