"""gemma2-2b — local/global alternating, logit softcap [arXiv:2408.00118]."""
from repro.configs.base import AttnConfig, ModelConfig

CONFIG = ModelConfig(
    arch_id="gemma2-2b", family="dense",
    n_layers=26, d_model=2304, d_ff=9216, vocab=256000,
    attn=AttnConfig(n_heads=8, n_kv_heads=4, head_dim=256,
                    softcap=50.0, sliding_window=4096, pattern=("l", "g")),
    act="gelu",
    source="arXiv:2408.00118 (Gemma2-2B: 26L d=2304 8H GQA kv=4 d_ff=9216 "
           "vocab=256000, alternating SWA+global, attn softcap 50)",
)


def reduced():
    from repro.configs.registry import SMOKE_RETRO
    return CONFIG.replace(
        n_layers=2, d_model=128, d_ff=256, vocab=512,
        attn=AttnConfig(n_heads=4, n_kv_heads=2, head_dim=32, softcap=50.0,
                        sliding_window=128, pattern=("l", "g")),
        dtype="float32", retro=SMOKE_RETRO)
