"""zamba2-1.2b — Mamba2 backbone + shared attention blocks [arXiv:2411.15242]."""
from repro.configs.base import AttnConfig, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    arch_id="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, d_ff=8192, vocab=32000,
    attn=AttnConfig(n_heads=32, n_kv_heads=32, head_dim=64),
    ssm=SSMConfig(kind="mamba2", state_size=64, head_dim=64, expand=2),
    shared_attn_every=6,
    source="arXiv:2411.15242 (Zamba2: 38L d=2048 32H MHA d_ff=8192 "
           "vocab=32000 ssm_state=64)",
)


def reduced():
    from repro.configs.registry import SMOKE_RETRO
    return CONFIG.replace(
        n_layers=2, d_model=128, d_ff=256, vocab=512, shared_attn_every=2,
        attn=AttnConfig(n_heads=4, n_kv_heads=4, head_dim=32),
        ssm=SSMConfig(kind="mamba2", state_size=16, head_dim=32, expand=2),
        dtype="float32", retro=SMOKE_RETRO)
