"""rwkv6-3b — Finch, attention-free data-dependent decay [arXiv:2404.05892]."""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    arch_id="rwkv6-3b", family="ssm",
    n_layers=32, d_model=2560, d_ff=8960, vocab=65536,
    ssm=SSMConfig(kind="rwkv6", head_dim=64),
    source="arXiv:2404.05892 (RWKV-6 Finch 3B: 32L d=2560 d_ff=8960 "
           "vocab=65536, attention-free)",
)


def reduced():
    from repro.configs.registry import SMOKE_RETRO
    return CONFIG.replace(
        n_layers=2, d_model=128, d_ff=256, vocab=512,
        ssm=SSMConfig(kind="rwkv6", head_dim=32),
        dtype="float32", retro=SMOKE_RETRO)
