"""whisper-tiny — enc-dec audio backbone, conv frontend stubbed [arXiv:2212.04356]."""
from repro.configs.base import AttnConfig, ModelConfig

CONFIG = ModelConfig(
    arch_id="whisper-tiny", family="audio",
    n_layers=4, d_model=384, d_ff=1536, vocab=51865,
    attn=AttnConfig(n_heads=6, n_kv_heads=6, head_dim=64),
    encoder_layers=4, encoder_frames=1500,
    source="arXiv:2212.04356 (Whisper tiny: 4L enc + 4L dec, d=384 6H "
           "d_ff=1536 vocab=51865; mel+conv frontend stubbed)",
)


def reduced():
    from repro.configs.registry import SMOKE_RETRO
    return CONFIG.replace(
        n_layers=2, d_model=128, d_ff=256, vocab=512,
        attn=AttnConfig(n_heads=4, n_kv_heads=4, head_dim=32),
        encoder_layers=2, encoder_frames=64,
        dtype="float32", retro=SMOKE_RETRO)
