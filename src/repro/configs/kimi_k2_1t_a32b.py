"""kimi-k2-1t-a32b — trillion-param MoE, 384 experts top-8 [arXiv:2501.kimi2]."""
from repro.configs.base import AttnConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch_id="kimi-k2-1t-a32b", family="moe",
    n_layers=61, d_model=7168, d_ff=2048, vocab=163840,
    attn=AttnConfig(n_heads=64, n_kv_heads=8, head_dim=128),
    moe=MoEConfig(num_experts=384, top_k=8, d_expert=2048),
    tie_embeddings=False,
    source="arXiv:2501.kimi2 (Kimi K2 paper table: 61L d=7168 64H GQA kv=8 "
           "per-expert d_ff=2048 vocab=163840 MoE 384e top-8)",
)


def reduced():
    from repro.configs.registry import SMOKE_RETRO
    return CONFIG.replace(
        n_layers=2, d_model=128, d_ff=128, vocab=512,
        attn=AttnConfig(n_heads=4, n_kv_heads=2, head_dim=32),
        moe=MoEConfig(num_experts=4, top_k=2, d_expert=128),
        dtype="float32", retro=SMOKE_RETRO)
