"""mixtral-8x22b — 8 experts top-2, SWA [arXiv:2401.04088]."""
from repro.configs.base import AttnConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch_id="mixtral-8x22b", family="moe",
    n_layers=56, d_model=6144, d_ff=16384, vocab=32768,
    attn=AttnConfig(n_heads=48, n_kv_heads=8, head_dim=128,
                    rope_theta=1_000_000.0, sliding_window=4096,
                    pattern=("l",)),
    moe=MoEConfig(num_experts=8, top_k=2, d_expert=16384),
    tie_embeddings=False,
    source="arXiv:2401.04088 (Mixtral 8x22B: 56L d=6144 48H GQA kv=8 "
           "per-expert d_ff=16384 vocab=32768, 8e top-2, SWA)",
)


def reduced():
    from repro.configs.registry import SMOKE_RETRO
    return CONFIG.replace(
        n_layers=2, d_model=128, d_ff=128, vocab=512,
        attn=AttnConfig(n_heads=4, n_kv_heads=2, head_dim=32,
                        sliding_window=128, pattern=("l",)),
        moe=MoEConfig(num_experts=4, top_k=2, d_expert=128),
        dtype="float32", retro=SMOKE_RETRO)
