"""Architecture registry + input specs for the assigned shape suite."""
from __future__ import annotations

import importlib
from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import InputShape, ModelConfig, RetroConfig

ARCH_IDS = (
    "zamba2_1p2b",
    "kimi_k2_1t_a32b",
    "gemma3_1b",
    "gemma2_9b",
    "minitron_8b",
    "rwkv6_3b",
    "llava_next_34b",
    "whisper_tiny",
    "gemma2_2b",
    "mixtral_8x22b",
)

# CLI aliases matching the assignment sheet
ALIASES = {
    "zamba2-1.2b": "zamba2_1p2b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "gemma3-1b": "gemma3_1b",
    "gemma2-9b": "gemma2_9b",
    "minitron-8b": "minitron_8b",
    "rwkv6-3b": "rwkv6_3b",
    "llava-next-34b": "llava_next_34b",
    "whisper-tiny": "whisper_tiny",
    "gemma2-2b": "gemma2_2b",
    "mixtral-8x22b": "mixtral_8x22b",
}


def get_config(arch: str) -> ModelConfig:
    arch = ALIASES.get(arch, arch)
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.CONFIG


def reduced_config(arch: str) -> ModelConfig:
    arch = ALIASES.get(arch, arch)
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.reduced()


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}


# Reduced-scale RetroConfig used by every smoke variant (same structure,
# test-friendly segment sizes).
SMOKE_RETRO = RetroConfig(avg_cluster=8, cluster_cap=16, prefill_segment=256,
                          update_segment=128, sink=4, local=32,
                          retrieval_frac=0.06, estimation_frac=0.25,
                          kmeans_iters=3)


def input_specs(cfg: ModelConfig, shape: InputShape, *,
                token_dtype=jnp.int32):
    """ShapeDtypeStruct stand-ins for the step's *batch* inputs.

    Modality frontends are stubbed per the assignment: vlm supplies patch
    embeddings, audio supplies frame embeddings, both at model width.
    """
    B, S = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    act_dtype = jnp.dtype(cfg.dtype)
    if shape.kind == "train":
        batch = {"tokens": sds((B, S), token_dtype),
                 "targets": sds((B, S), token_dtype)}
    elif shape.kind == "prefill":
        batch = {"tokens": sds((B, S), token_dtype)}
    else:  # decode: one new token; the KV/index state carries seq_len context
        batch = {"token": sds((B,), token_dtype)}
    if cfg.family == "vlm" and shape.kind != "decode":
        batch["patch_embeds"] = sds((B, cfg.num_patch_tokens, cfg.d_model),
                                    act_dtype)
    if cfg.family == "audio" and shape.kind != "decode":
        batch["frames"] = sds((B, cfg.encoder_frames, cfg.d_model), act_dtype)
    return batch


def materialize_batch(cfg: ModelConfig, shape: InputShape, key=None):
    """Concrete random batch matching input_specs (for tests/benchmarks)."""
    key = key if key is not None else jax.random.PRNGKey(0)
    specs = input_specs(cfg, shape)
    out = {}
    for name, spec in specs.items():
        key, sub = jax.random.split(key)
        if jnp.issubdtype(spec.dtype, jnp.integer):
            out[name] = jax.random.randint(sub, spec.shape, 0, cfg.vocab,
                                           dtype=spec.dtype)
        else:
            out[name] = jax.random.normal(sub, spec.shape, jnp.float32
                                          ).astype(spec.dtype)
    return out
