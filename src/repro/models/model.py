"""Unified model API over all architecture families.

    params           = init_params(cfg, key)
    logits, aux      = apply_train(params, cfg, batch)
    loss             = lm_loss(params, cfg, batch)
    logits, state    = apply_prefill(params, cfg, batch, runtime=...)
    logits, state    = apply_decode(params, cfg, state, token, runtime=...)
    state            = make_serve_state(cfg, B, seq_len, runtime=...)

Chunked admission (attention families; others pass through to blocking):

    cs            = make_prefill_chunk_state(cfg, B, max_ctx, chunk=C, ...)
    logits, cs    = apply_prefill_chunk(params, cfg, chunk_batch, cs, ...)
    state         = finalize_prefill_chunk(cfg, cs, total_len=L, ...)

``batch`` dict keys: tokens (B, T) int32; targets (B, T) int32 (train);
patch_embeds (B, P, D) for vlm; frames (B, F, D) for audio.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.zones import ZonePlan, plan_zones
from repro.models import encdec, hybrid, rwkv6, transformer

ATTN_FAMILIES = ("dense", "moe", "vlm")


def init_params(cfg: ModelConfig, key) -> Any:
    if cfg.family in ATTN_FAMILIES:
        return transformer.init_transformer(cfg, key)
    if cfg.family == "ssm":
        return rwkv6.init_rwkv6(cfg, key)
    if cfg.family == "hybrid":
        return hybrid.init_hybrid(cfg, key)
    if cfg.family == "audio":
        return encdec.init_encdec(cfg, key)
    raise ValueError(cfg.family)


def param_specs(cfg: ModelConfig, key=None) -> Any:
    """ShapeDtypeStruct pytree of the parameters — no allocation."""
    k = jax.random.PRNGKey(0) if key is None else key
    return jax.eval_shape(lambda: init_params(cfg, k))


def _hidden_forward(params, cfg: ModelConfig, batch) -> Tuple[jax.Array, Any]:
    if cfg.family in ATTN_FAMILIES:
        return transformer.forward(params, cfg, batch["tokens"],
                                   batch.get("patch_embeds"))
    if cfg.family == "ssm":
        return rwkv6.forward(params, cfg, batch["tokens"])
    if cfg.family == "hybrid":
        return hybrid.forward(params, cfg, batch["tokens"])
    if cfg.family == "audio":
        return encdec.forward(params, cfg, batch["tokens"], batch["frames"])
    raise ValueError(cfg.family)


def apply_train(params, cfg: ModelConfig, batch):
    """-> (logits (B, T, V) f32, aux_loss)."""
    x, aux = _hidden_forward(params, cfg, batch)
    if cfg.family in ATTN_FAMILIES:
        logits = transformer.unembed(params, cfg, x)
    else:
        logits = (x @ params["embed"].T).astype(jnp.float32)
    return logits, aux


def lm_loss(params, cfg: ModelConfig, batch) -> jax.Array:
    logits, aux = apply_train(params, cfg, batch)
    targets = batch["targets"]
    mask = batch.get("loss_mask")
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    if mask is not None:
        nll = nll * mask
        denom = jnp.maximum(jnp.sum(mask), 1.0)
    else:
        denom = nll.size
    return jnp.sum(nll) / denom + aux


def apply_prefill(params, cfg: ModelConfig, batch, *, runtime: str = "retro",
                  plan: Optional[ZonePlan] = None, gen_headroom: int = 4096,
                  lengths=None, cache_len: Optional[int] = None):
    """``lengths``: optional (B,) true prompt lengths for right-padded ragged
    batches (attention families only — recurrent prefills consume pads).
    ``cache_len``: dense-cache capacity override (continuous batching)."""
    if cfg.family in ATTN_FAMILIES:
        return transformer.prefill(params, cfg, batch["tokens"],
                                   batch.get("patch_embeds"), runtime=runtime,
                                   plan=plan, gen_headroom=gen_headroom,
                                   lengths=lengths, cache_len=cache_len)
    assert lengths is None, \
        f"ragged (right-padded) prefill unsupported for family {cfg.family}"
    if cfg.family == "ssm":
        return rwkv6.prefill(params, cfg, batch["tokens"])
    if cfg.family == "hybrid":
        return hybrid.prefill(params, cfg, batch["tokens"], runtime=runtime,
                              plan=plan, gen_headroom=gen_headroom,
                              cache_len=cache_len)
    if cfg.family == "audio":
        return encdec.prefill(params, cfg, batch["tokens"], batch["frames"],
                              runtime=runtime, plan=plan,
                              gen_headroom=gen_headroom, cache_len=cache_len)
    raise ValueError(cfg.family)


def supports_chunked_prefill(cfg: ModelConfig, runtime: str = "retro") -> bool:
    """Chunked (interleaved) admission is implemented for the attention
    families under both runtimes; recurrent prefills (ssm/hybrid) and the
    enc-dec decoder consume their prompt in one pass — engines fall back to
    blocking admission for them (see ``ServeEngine``)."""
    return cfg.family in ATTN_FAMILIES


def make_prefill_chunk_state(cfg: ModelConfig, B: int, max_ctx: int, *,
                             runtime: str = "retro", chunk: int,
                             gen_headroom: int = 4096):
    if cfg.family in ATTN_FAMILIES:
        return transformer.init_prefill_chunk_state(
            cfg, B, max_ctx, runtime=runtime, chunk=chunk,
            gen_headroom=gen_headroom)
    raise NotImplementedError(
        f"chunked prefill unsupported for family {cfg.family}; "
        "use blocking admission (apply_prefill)")


def apply_prefill_chunk(params, cfg: ModelConfig, batch, state, *,
                        runtime: str = "retro", chunk_lens=None):
    """Consume the next right-padded prompt chunk ``batch['tokens']`` (B, C).

    Chunk queries attend causally to the prior prompt prefix + the chunk
    itself; the wave index (retro) is built incrementally and bit-identically
    to the monolithic build. Returns (last-valid-position logits, new state).
    Pass-through families (encdec/hybrid/ssm) raise — callers fall back to
    ``apply_prefill`` (blocking admission)."""
    if cfg.family in ATTN_FAMILIES:
        return transformer.prefill_chunk(
            params, cfg, batch["tokens"], state, runtime=runtime,
            chunk_lens=chunk_lens, patch_embeds=batch.get("patch_embeds"))
    raise NotImplementedError(
        f"chunked prefill unsupported for family {cfg.family}; "
        "use blocking admission (apply_prefill)")


def finalize_prefill_chunk(cfg: ModelConfig, state, *, runtime: str = "retro",
                           total_len: int):
    """Close a chunked admission into a decode-ready ServeState."""
    if cfg.family in ATTN_FAMILIES:
        return transformer.finalize_prefill_chunk(
            cfg, state, runtime=runtime, total_len=total_len)
    raise NotImplementedError(
        f"chunked prefill unsupported for family {cfg.family}")


def apply_decode(params, cfg: ModelConfig, state, token, *,
                 runtime: str = "retro", plan: Optional[ZonePlan] = None,
                 seq_len: Optional[int] = None, gen_headroom: int = 4096,
                 inline_flush: bool = False, active=None,
                 attn_impl: Optional[str] = None):
    """``active``: optional (B,) bool slot mask — inactive (free) rows of a
    continuous batch skip their KV-state append so counters never drift.

    ``attn_impl``: wave-attention implementation for the retro runtime —
    "jnp" (reference) or "fused" (gather-free paged Pallas kernel,
    interpret-mode on CPU); None defers to ``cfg.retro.attn_impl``."""
    if plan is None and cfg.family != "ssm":
        assert seq_len is not None, "need plan or seq_len"
        plan = plan_zones(seq_len, cfg.retro, gen_headroom)
    if cfg.family in ATTN_FAMILIES:
        return transformer.decode_step(params, cfg, state, token,
                                       runtime=runtime, plan=plan,
                                       inline_flush=inline_flush,
                                       active=active, attn_impl=attn_impl)
    if cfg.family == "ssm":
        return rwkv6.decode_step(params, cfg, state, token)
    if cfg.family == "hybrid":
        return hybrid.decode_step(params, cfg, state, token, runtime=runtime,
                                  plan=plan, inline_flush=inline_flush,
                                  active=active, attn_impl=attn_impl)
    if cfg.family == "audio":
        return encdec.decode_step(params, cfg, state, token, runtime=runtime,
                                  plan=plan, inline_flush=inline_flush,
                                  active=active, attn_impl=attn_impl)
    raise ValueError(cfg.family)


def supports_offload(cfg: ModelConfig, runtime: str = "retro") -> bool:
    """The host-offload wave buffer (device block cache over host-resident
    cluster stores) is implemented for the attention families under the retro
    runtime; recurrent/enc-dec families and the dense-cache runtime have no
    cluster stores to offload."""
    return runtime == "retro" and cfg.family in ATTN_FAMILIES


def offload_decode_fns(cfg: ModelConfig):
    """Per-layer jit-able pieces of the offload decode step:
    ``(embed, rank, attend, unembed, flush)`` — see
    ``transformer.offload_decode_rank`` / ``offload_decode_attend``. The
    engine owns the control plane between the two halves."""
    if cfg.family not in ATTN_FAMILIES:
        raise NotImplementedError(
            f"host-offload decode unsupported for family {cfg.family}")
    return (transformer.decode_embed, transformer.offload_decode_rank,
            transformer.offload_decode_attend, transformer.decode_unembed,
            transformer.offload_flush)


def flush_state(cfg: ModelConfig, state, *, runtime: str = "retro"):
    """Run the decode-time segmented-clustering index update on every layer's
    wave state (the paper's asynchronous 1K-token update). No-op for dense
    caches and recurrent states."""
    if runtime != "retro" or cfg.family == "ssm":
        return state
    from repro.core.wave_index import flush_segment

    def flush_stack(stacked):
        return jax.vmap(lambda st: flush_segment(st, cfg.retro))(stacked)

    if cfg.family in ATTN_FAMILIES:
        return state._replace(kv=flush_stack(state.kv))
    if cfg.family == "hybrid":
        return state._replace(attn_kv=flush_stack(state.attn_kv))
    if cfg.family == "audio":
        return state._replace(self_kv=flush_stack(state.self_kv))
    return state


def needs_flush(cfg: ModelConfig, appended_since_flush: int) -> bool:
    """The staging buffer holds local + update_segment tokens; it must be
    flushed every ``update_segment`` appended tokens."""
    return appended_since_flush >= cfg.retro.update_segment


def make_serve_state(cfg: ModelConfig, B: int, seq_len: int, *,
                     runtime: str = "retro", gen_headroom: int = 4096,
                     zero_fill: bool = False):
    if cfg.family in ATTN_FAMILIES:
        return transformer.init_serve_state(cfg, B, seq_len, runtime=runtime,
                                            gen_headroom=gen_headroom,
                                            zero_fill=zero_fill)
    if cfg.family == "ssm":
        return rwkv6.init_serve_state(cfg, B)
    if cfg.family == "hybrid":
        return hybrid.init_serve_state(cfg, B, seq_len, runtime=runtime,
                                       gen_headroom=gen_headroom,
                                       zero_fill=zero_fill)
    if cfg.family == "audio":
        return encdec.init_serve_state(cfg, B, seq_len, runtime=runtime,
                                       gen_headroom=gen_headroom,
                                       zero_fill=zero_fill)
    raise ValueError(cfg.family)


def serve_state_specs(cfg: ModelConfig, B: int, seq_len: int, *,
                      runtime: str = "retro", gen_headroom: int = 4096):
    return jax.eval_shape(
        lambda: make_serve_state(cfg, B, seq_len, runtime=runtime,
                                 gen_headroom=gen_headroom))
