"""Zamba2-style hybrid backbone (arXiv:2411.15242): a stack of Mamba-2 blocks
with ONE shared GQA attention block (single weight set) applied every
``shared_attn_every`` layers. The wave index applies to the shared-attention
sites only — each application site has its own KV/index state (same weights,
different depth => different K/V).
"""
from __future__ import annotations

import math
from typing import Any, Dict, List, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import attention as wa
from repro.core.wave_index import (append_token, init_wave_state, maybe_flush,
                                   prefill_build)
from repro.core.zones import ZonePlan, plan_zones
from repro.models import layers as L
from repro.models import mamba2
from repro.models.layers import dense_init, rms_norm


def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


def attn_sites(cfg: ModelConfig) -> List[int]:
    k = cfg.shared_attn_every
    return [i for i in range(cfg.n_layers) if i % k == k - 1]


def init_hybrid(cfg: ModelConfig, key) -> Dict[str, Any]:
    ks = jax.random.split(key, cfg.n_layers + 4)
    layers = jax.vmap(lambda k: mamba2.init_layer(k, cfg))(ks[: cfg.n_layers])
    a = cfg.attn
    shared = {
        "ln1": jnp.zeros((cfg.d_model,), _dtype(cfg)),
        "ln2": jnp.zeros((cfg.d_model,), _dtype(cfg)),
        "attn": L.init_attention(ks[-3], cfg.d_model, a.n_heads, a.n_kv_heads,
                                 a.head_dim, _dtype(cfg)),
        "mlp": L.init_mlp(ks[-2], cfg.d_model, cfg.d_ff, _dtype(cfg)),
    }
    return {
        "embed": dense_init(ks[-1], (cfg.vocab, cfg.d_model), scale=cfg.d_model ** -0.5,
                            dtype=_dtype(cfg)),
        "layers": layers,
        "shared": shared,
        "final_norm": jnp.zeros((cfg.d_model,), _dtype(cfg)),
    }


def _shared_block_seq(sp, cfg: ModelConfig, x, positions):
    """Shared attention + MLP block over a full sequence (train/prefill)."""
    a = cfg.attn
    B, T, _ = x.shape
    h = rms_norm(x, sp["ln1"], cfg.norm_eps)
    q, k, v = L.attention_qkv(sp["attn"], h, a.n_heads, a.n_kv_heads,
                              a.head_dim, positions, a.rope_theta)
    o = L.flash_attention_jnp(q, k, v, causal=True, softcap=a.softcap)
    x = x + o.reshape(B, T, -1) @ sp["attn"]["wo"]
    h = rms_norm(x, sp["ln2"], cfg.norm_eps)
    x = x + L.mlp_apply(sp["mlp"], h, cfg.act)
    return x, (k, v)


def _group_layout(cfg: ModelConfig):
    """Layers come in groups of (shared_attn_every mamba blocks + shared attn)
    with a mamba-only remainder — scanned as groups to keep HLO compact."""
    G = cfg.shared_attn_every
    n_groups = cfg.n_layers // G
    rem = cfg.n_layers - n_groups * G
    return G, n_groups, rem


def _group_params(params, cfg: ModelConfig):
    G, n_groups, rem = _group_layout(cfg)
    grouped = jax.tree.map(
        lambda a: a[: n_groups * G].reshape((n_groups, G) + a.shape[1:]),
        params["layers"])
    tail = jax.tree.map(lambda a: a[n_groups * G:], params["layers"])
    return grouped, tail, G, n_groups, rem


def forward(params, cfg: ModelConfig, tokens):
    x = params["embed"][tokens] * math.sqrt(cfg.d_model)
    T = x.shape[1]
    positions = jnp.arange(T)
    grouped, tail, G, n_groups, rem = _group_params(params, cfg)

    @jax.checkpoint
    def group_fn(x, gp):
        def inner(x, lp):
            return mamba2.layer_apply_seq(lp, cfg, x), None
        x, _ = jax.lax.scan(inner, x, gp)
        x, _ = _shared_block_seq(params["shared"], cfg, x, positions)
        return x, None

    if n_groups > 0:
        x, _ = jax.lax.scan(group_fn, x, grouped)
    for i in range(rem):
        lp = jax.tree.map(lambda a: a[i], tail)
        x = jax.checkpoint(lambda x, lp: mamba2.layer_apply_seq(lp, cfg, x))(
            x, lp)
    return rms_norm(x, params["final_norm"], cfg.norm_eps), 0.0


class HybridServeState(NamedTuple):
    mamba: Any              # stacked (n_layers, ...) Mamba2LayerState
    attn_kv: Any            # stacked (n_sites, ...) WaveState or DenseCache


def prefill(params, cfg: ModelConfig, tokens, *, runtime: str = "retro",
            plan: ZonePlan = None, gen_headroom: int = 4096,
            cache_len=None):
    B, T = tokens.shape
    retro = cfg.retro
    if plan is None:
        plan = plan_zones(T, retro, gen_headroom)
    total = cache_len if cache_len is not None else T + gen_headroom
    x = params["embed"][tokens] * math.sqrt(cfg.d_model)
    positions = jnp.arange(T)
    grouped, tail, G, n_groups, rem = _group_params(params, cfg)

    def build_kv(k, v):
        if runtime == "retro":
            return prefill_build(k, v, retro, plan.m_max, dtype=_dtype(cfg))
        return wa.DenseCache(
            jnp.swapaxes(jnp.pad(k, ((0, 0), (0, total - T),
                                     (0, 0), (0, 0))), 1, 2),
            jnp.swapaxes(jnp.pad(v, ((0, 0), (0, total - T),
                                     (0, 0), (0, 0))), 1, 2),
            jnp.full((B,), T, jnp.int32))

    def group_fn(x, gp):
        def inner(x, lp):
            x, mst = mamba2.layer_apply_seq(lp, cfg, x, return_state=True)
            return x, mst
        x, msts = jax.lax.scan(inner, x, gp)               # msts: (G, ...)
        x, (k, v) = _shared_block_seq(params["shared"], cfg, x, positions)
        return x, (msts, build_kv(k, v))

    if n_groups > 0:
        x, (m_grp, kv_states) = jax.lax.scan(group_fn, x, grouped)
        # (n_groups, G, ...) -> (n_groups*G, ...)
        m_states = jax.tree.map(
            lambda a: a.reshape((n_groups * G,) + a.shape[2:]), m_grp)
    else:
        m_states, kv_states = None, None
    tail_states = []
    for i in range(rem):
        lp = jax.tree.map(lambda a: a[i], tail)
        x, mst = mamba2.layer_apply_seq(lp, cfg, x, return_state=True)
        tail_states.append(mst)
    if tail_states:
        tail_stack = jax.tree.map(lambda *xs: jnp.stack(xs), *tail_states)
        m_states = tail_stack if m_states is None else jax.tree.map(
            lambda a, b: jnp.concatenate([a, b], axis=0), m_states, tail_stack)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = (x[:, -1] @ params["embed"].T).astype(jnp.float32)
    return logits, HybridServeState(mamba=m_states, attn_kv=kv_states)


def decode_step(params, cfg: ModelConfig, state: HybridServeState, token, *,
                runtime: str = "retro", plan: ZonePlan,
                inline_flush: bool = False, active=None, attn_impl=None):
    a, retro = cfg.attn, cfg.retro
    impl = wa.resolve_attn_impl(attn_impl or retro.attn_impl)
    x = params["embed"][token] * math.sqrt(cfg.d_model)
    B = x.shape[0]
    sites = attn_sites(cfg)
    new_m, new_kv = [], []
    for i in range(cfg.n_layers):
        lp = jax.tree.map(lambda arr: arr[i], params["layers"])
        mst = jax.tree.map(lambda arr: arr[i], state.mamba)
        x, mst = mamba2.layer_decode_step(lp, cfg, mst, x)
        new_m.append(mst)
        if i in set(sites):
            s_idx = sites.index(i)
            kst = jax.tree.map(lambda arr: arr[s_idx], state.attn_kv)
            sp = params["shared"]
            h = rms_norm(x, sp["ln1"], cfg.norm_eps)
            pos = kst.length                                 # (B,) per-row
            q, k, v = L.attention_qkv(sp["attn"], h[:, None, :], a.n_heads,
                                      a.n_kv_heads, a.head_dim,
                                      pos[:, None], a.rope_theta)
            q, k, v = q[:, 0], k[:, 0], v[:, 0]
            if runtime == "retro":
                kst = append_token(kst, k, v, active=active)
                o = wa.wave_attention_decode(q, kst, retro, plan,
                                             softcap=a.softcap,
                                             impl=impl).out
                if inline_flush:
                    kst = maybe_flush(kst, retro)
            else:
                kst = wa.dense_cache_append(kst, k, v, active=active)
                o = wa.full_attention_decode(q, kst, softcap=a.softcap)
            x = x + o.reshape(B, -1) @ sp["attn"]["wo"]
            h = rms_norm(x, sp["ln2"], cfg.norm_eps)
            x = x + L.mlp_apply(sp["mlp"], h, cfg.act)
            new_kv.append(kst)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = (x @ params["embed"].T).astype(jnp.float32)
    return logits, HybridServeState(
        mamba=jax.tree.map(lambda *xs: jnp.stack(xs), *new_m),
        attn_kv=jax.tree.map(lambda *xs: jnp.stack(xs), *new_kv))


def init_serve_state(cfg: ModelConfig, B: int, seq_len: int, *,
                     runtime: str = "retro", gen_headroom: int = 4096,
                     zero_fill: bool = False) -> HybridServeState:
    retro = cfg.retro
    a = cfg.attn
    plan = plan_zones(seq_len, retro, gen_headroom)
    n_sites = len(attn_sites(cfg))

    def one_kv(_):
        if runtime == "retro":
            st = init_wave_state(B, a.n_kv_heads, a.head_dim, plan.m_max,
                                 retro, _dtype(cfg))
            if not zero_fill:
                st = st._replace(
                    length=jnp.full((B,), seq_len, jnp.int32),
                    local_len=jnp.full((B,), retro.local, jnp.int32),
                    n_clusters=jnp.full((B,), plan.m_max, jnp.int32))
            return st
        cap = seq_len + gen_headroom
        return wa.DenseCache(
            jnp.zeros((B, a.n_kv_heads, cap, a.head_dim), _dtype(cfg)),
            jnp.zeros((B, a.n_kv_heads, cap, a.head_dim), _dtype(cfg)),
            jnp.full((B,), 0 if zero_fill else seq_len, jnp.int32))

    mamba = jax.vmap(lambda _: mamba2.init_layer_state(cfg, B))(
        jnp.arange(cfg.n_layers))
    kv = jax.vmap(one_kv)(jnp.arange(n_sites))
    return HybridServeState(mamba=mamba, attn_kv=kv)
