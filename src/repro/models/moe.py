"""Sort-based top-k MoE FFN (MaxText-style dropping implementation).

Tokens are routed to their top-k experts, sorted by expert id, packed into a
fixed-capacity (E, C, D) buffer (static shapes — no ragged ops), run through
batched expert MLPs on the MXU, and scattered back. Tokens beyond an expert's
capacity are dropped (standard GShard semantics, capacity_factor controls the
drop rate). Expert weights are stacked on a leading E axis so they shard over
the 'model' mesh axis (expert parallelism).
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.models.layers import dense_init


def init_moe(key, d_model: int, moe: MoEConfig, dtype):
    ks = jax.random.split(key, 4)
    E, F = moe.num_experts, moe.d_expert
    return {
        "router": dense_init(ks[0], (d_model, E), dtype=jnp.float32),
        "w_gate": jax.vmap(lambda k: dense_init(k, (d_model, F), dtype=dtype))(
            jax.random.split(ks[1], E)),
        "w_up": jax.vmap(lambda k: dense_init(k, (d_model, F), dtype=dtype))(
            jax.random.split(ks[2], E)),
        "w_down": jax.vmap(lambda k: dense_init(k, (F, d_model), dtype=dtype))(
            jax.random.split(ks[3], E)),
    }


def expert_capacity(n_tokens: int, moe: MoEConfig) -> int:
    c = math.ceil(n_tokens * moe.top_k / moe.num_experts * moe.capacity_factor)
    return max(8, int(math.ceil(c / 8) * 8))               # MXU-friendly multiple


def moe_apply_grouped(p, x: jax.Array, moe: MoEConfig, act: str = "silu",
                      groups: int = 1) -> Tuple[jax.Array, jax.Array]:
    """Sharding-friendly dispatch (§Perf iteration on kimi x train_4k).

    The single-group path sorts ALL token-replicas globally; under pjit with
    tokens sharded on 'data' that argsort/gather chain forces all-gathers of
    (T·k, D) activations. Grouping the dispatch into ``groups`` independent
    token groups (aligned with the data axis) keeps every sort/pack local to
    its shard — the only remaining collective is the irreducible
    expert-parallel psum of the outputs.
    """
    T, D = x.shape
    if groups <= 1 or T % groups:
        return moe_apply(p, x, moe, act)
    xg = x.reshape(groups, T // groups, D)
    y, aux = jax.vmap(lambda xi: moe_apply(p, xi, moe, act))(xg)
    return y.reshape(T, D), jnp.mean(aux)


def moe_apply(p, x: jax.Array, moe: MoEConfig, act: str = "silu"
              ) -> Tuple[jax.Array, jax.Array]:
    """x: (T, D) -> (y (T, D), aux_loss scalar)."""
    T, D = x.shape
    E, K = moe.num_experts, moe.top_k
    C = expert_capacity(T, moe)

    logits = x.astype(jnp.float32) @ p["router"]           # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, K)                 # (T, K)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

    # ---- pack: sort token-replicas by expert id ----------------------------
    e_flat = top_e.reshape(-1)                             # (T*K,)
    w_flat = top_p.reshape(-1)
    tok_id = jnp.repeat(jnp.arange(T), K)
    order = jnp.argsort(e_flat, stable=True)
    se, sw, st = e_flat[order], w_flat[order], tok_id[order]
    starts = jnp.searchsorted(se, jnp.arange(E), side="left")
    rank = jnp.arange(T * K) - starts[se]                  # slot within expert
    keep = rank < C

    buf = jnp.zeros((E, C, D), x.dtype)
    buf = buf.at[se, rank].set(x[st], mode="drop")

    # ---- batched expert MLP -------------------------------------------------
    g = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])
    g = jax.nn.silu(g) if act == "silu" else jax.nn.gelu(g, approximate=True)
    u = jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    out_buf = jnp.einsum("ecf,efd->ecd", g * u, p["w_down"])

    # ---- unpack + combine ----------------------------------------------------
    y_sorted = out_buf[se, jnp.minimum(rank, C - 1)]       # (T*K, D)
    y_sorted = jnp.where(keep[:, None], y_sorted, 0.0)
    contrib = y_sorted * sw[:, None].astype(y_sorted.dtype)
    y = jnp.zeros((T, D), contrib.dtype).at[st].add(contrib)

    # ---- load-balance auxiliary loss (Switch-style) -------------------------
    frac_tokens = jnp.mean(
        jax.nn.one_hot(top_e[:, 0], E, dtype=jnp.float32), axis=0)
    frac_probs = jnp.mean(probs, axis=0)
    aux = moe.aux_loss_weight * E * jnp.sum(frac_tokens * frac_probs)
    return y.astype(x.dtype), aux
