"""Mamba-2 (SSD) block — used by the zamba2 hybrid backbone.

State-space recurrence per head h with scalar decay:
    S_t = exp(dt_t * A_h) * S_{t-1} + dt_t * x_t  (x)  B_t
    y_t = S_t @ C_t + D_h * x_t
Training runs a `lax.scan` over time; decode is a single O(1) update.
A short causal depthwise conv precedes (x, B, C) as in the reference model.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init, rms_norm
from repro.models.scan_utils import remat_chunked_scan


def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


class Mamba2LayerState(NamedTuple):
    ssm: jax.Array          # (B, H, hd, N) recurrent state
    conv: jax.Array         # (B, conv_k - 1, conv_dim) conv history


def dims(cfg: ModelConfig):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    H = d_in // s.head_dim
    return d_in, H, s.head_dim, s.state_size, s.conv_kernel


def init_layer(key, cfg: ModelConfig):
    D = cfg.d_model
    d_in, H, hd, N, ck = dims(cfg)
    conv_dim = d_in + 2 * N
    ks = jax.random.split(key, 4)
    dt = _dtype(cfg)
    return {
        "ln": jnp.zeros((D,), dt),
        # in_proj -> [z, x, B, C, dt]
        "in_proj": dense_init(ks[0], (D, 2 * d_in + 2 * N + H), dtype=dt),
        "conv_w": (jax.random.normal(ks[1], (ck, conv_dim)) * 0.1).astype(dt),
        "conv_b": jnp.zeros((conv_dim,), dt),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "out_norm": jnp.zeros((d_in,), dt),
        "out_proj": dense_init(ks[2], (d_in, D), dtype=dt),
    }


def _split_proj(cfg, zxbcdt):
    d_in, H, hd, N, _ = dims(cfg)
    z = zxbcdt[..., :d_in]
    x = zxbcdt[..., d_in:2 * d_in]
    Bm = zxbcdt[..., 2 * d_in:2 * d_in + N]
    Cm = zxbcdt[..., 2 * d_in + N:2 * d_in + 2 * N]
    dtv = zxbcdt[..., 2 * d_in + 2 * N:]
    return z, x, Bm, Cm, dtv


def layer_apply_seq(lp, cfg: ModelConfig, xin, return_state: bool = False):
    """Training path. xin: (B, T, D) -> (B, T, D) [, final Mamba2LayerState]."""
    B, T, D = xin.shape
    d_in, H, hd, N, ck = dims(cfg)
    h = rms_norm(xin, lp["ln"], cfg.norm_eps)
    z, x, Bm, Cm, dtv = _split_proj(cfg, h @ lp["in_proj"])

    # causal depthwise conv over (x, B, C)
    xbc = jnp.concatenate([x, Bm, Cm], axis=-1)
    pad = jnp.pad(xbc, ((0, 0), (ck - 1, 0), (0, 0)))
    conv = sum(pad[:, i:i + T] * lp["conv_w"][i] for i in range(ck))
    conv = jax.nn.silu(conv + lp["conv_b"])
    x, Bm, Cm = (conv[..., :d_in], conv[..., d_in:d_in + N],
                 conv[..., d_in + N:])

    xh = x.reshape(B, T, H, hd).astype(jnp.float32)
    dtv = jax.nn.softplus(dtv.astype(jnp.float32) + lp["dt_bias"])     # (B,T,H)
    A = -jnp.exp(lp["A_log"])                                           # (H,)
    decay = jnp.exp(dtv * A)                                            # (B,T,H)
    Bf = Bm.astype(jnp.float32)
    Cf = Cm.astype(jnp.float32)

    def step(S, inp):
        xt, bt, ct, dct, dtt = inp                                      # per-t
        S = dct[..., None, None] * S + jnp.einsum(
            "bhp,bn->bhpn", xt * dtt[..., None], bt)
        y = jnp.einsum("bhpn,bn->bhp", S, ct)
        return S, y

    S0 = jnp.zeros((B, H, hd, N), jnp.float32)
    S_fin, ys = remat_chunked_scan(step, S0, (jnp.swapaxes(xh, 0, 1),
                                        jnp.swapaxes(Bf, 0, 1),
                                        jnp.swapaxes(Cf, 0, 1),
                                        jnp.swapaxes(decay, 0, 1),
                                        jnp.swapaxes(dtv, 0, 1)))
    y = jnp.swapaxes(ys, 0, 1) + lp["D"][None, None, :, None] * xh
    y = y.reshape(B, T, d_in).astype(xin.dtype)
    y = y * jax.nn.silu(z)
    y = rms_norm(y, lp["out_norm"], cfg.norm_eps)
    out = xin + y @ lp["out_proj"]
    if return_state:
        st = Mamba2LayerState(ssm=S_fin, conv=xbc[:, T - (ck - 1):].astype(_dtype(cfg)))
        return out, st
    return out


def init_layer_state(cfg: ModelConfig, B: int) -> Mamba2LayerState:
    d_in, H, hd, N, ck = dims(cfg)
    return Mamba2LayerState(
        ssm=jnp.zeros((B, H, hd, N), jnp.float32),
        conv=jnp.zeros((B, ck - 1, d_in + 2 * N), _dtype(cfg)))


def layer_decode_step(lp, cfg: ModelConfig, st: Mamba2LayerState, xin):
    """Decode path. xin: (B, D) -> (out (B, D), new state)."""
    B, D = xin.shape
    d_in, H, hd, N, ck = dims(cfg)
    h = rms_norm(xin, lp["ln"], cfg.norm_eps)
    z, x, Bm, Cm, dtv = _split_proj(cfg, h @ lp["in_proj"])

    xbc = jnp.concatenate([x, Bm, Cm], axis=-1)                         # (B, conv_dim)
    hist = jnp.concatenate([st.conv, xbc[:, None, :]], axis=1)          # (B, ck, cd)
    conv = jnp.einsum("bkc,kc->bc", hist, lp["conv_w"].astype(hist.dtype))
    conv = jax.nn.silu(conv + lp["conv_b"])
    x, Bm, Cm = (conv[..., :d_in], conv[..., d_in:d_in + N],
                 conv[..., d_in + N:])

    xh = x.reshape(B, H, hd).astype(jnp.float32)
    dtv = jax.nn.softplus(dtv.astype(jnp.float32) + lp["dt_bias"])      # (B,H)
    decay = jnp.exp(dtv * (-jnp.exp(lp["A_log"])))
    S = decay[..., None, None] * st.ssm + jnp.einsum(
        "bhp,bn->bhpn", xh * dtv[..., None], Bm.astype(jnp.float32))
    y = jnp.einsum("bhpn,bn->bhp", S, Cm.astype(jnp.float32))
    y = y + lp["D"][None, :, None] * xh
    y = y.reshape(B, d_in).astype(xin.dtype) * jax.nn.silu(z)
    y = rms_norm(y, lp["out_norm"], cfg.norm_eps)
    new = Mamba2LayerState(ssm=S, conv=hist[:, 1:])
    return xin + y @ lp["out_proj"], new
