"""Whisper-style encoder-decoder backbone (arXiv:2212.04356).

The mel-spectrogram + conv feature extractor is a STUB per the assignment:
``input_specs()`` supplies precomputed frame embeddings (B, frames, D). We
implement the transformer backbone: a bidirectional encoder over frames and a
causal decoder with self-attention (dense cache or wave index) plus
cross-attention to the encoder output. Cross-attention K/V is computed once at
prefill and is "steady by construction" (fixed 1500 frames).
"""
from __future__ import annotations

import math
from typing import Any, Dict, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import attention as wa
from repro.core.wave_index import (append_token, init_wave_state, maybe_flush,
                                   prefill_build)
from repro.core.zones import ZonePlan, plan_zones
from repro.models import layers as L
from repro.models.layers import dense_init, rms_norm, sinusoidal_positions


def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


def init_enc_layer(key, cfg: ModelConfig):
    a = cfg.attn
    k1, k2 = jax.random.split(key)
    return {
        "ln1": jnp.zeros((cfg.d_model,), _dtype(cfg)),
        "ln2": jnp.zeros((cfg.d_model,), _dtype(cfg)),
        "attn": L.init_attention(k1, cfg.d_model, a.n_heads, a.n_kv_heads,
                                 a.head_dim, _dtype(cfg)),
        "mlp": L.init_mlp(k2, cfg.d_model, cfg.d_ff, _dtype(cfg)),
    }


def init_dec_layer(key, cfg: ModelConfig):
    a = cfg.attn
    k1, k2, k3 = jax.random.split(key, 3)
    p = init_enc_layer(k1, cfg)
    p["ln_x"] = jnp.zeros((cfg.d_model,), _dtype(cfg))
    p["xattn"] = L.init_attention(k2, cfg.d_model, a.n_heads, a.n_kv_heads,
                                  a.head_dim, _dtype(cfg))
    return p


def init_encdec(cfg: ModelConfig, key) -> Dict[str, Any]:
    ks = jax.random.split(key, 4)
    enc = jax.vmap(lambda k: init_enc_layer(k, cfg))(
        jax.random.split(ks[0], cfg.encoder_layers))
    dec = jax.vmap(lambda k: init_dec_layer(k, cfg))(
        jax.random.split(ks[1], cfg.n_layers))
    return {
        "embed": dense_init(ks[2], (cfg.vocab, cfg.d_model), scale=cfg.d_model ** -0.5,
                            dtype=_dtype(cfg)),
        "enc_layers": enc,
        "dec_layers": dec,
        "enc_norm": jnp.zeros((cfg.d_model,), _dtype(cfg)),
        "final_norm": jnp.zeros((cfg.d_model,), _dtype(cfg)),
    }


def encode(params, cfg: ModelConfig, frames):
    """frames: (B, F, D) stub embeddings -> encoder hidden (B, F, D)."""
    B, F, D = frames.shape
    a = cfg.attn
    x = frames.astype(_dtype(cfg)) + sinusoidal_positions(F, D).astype(_dtype(cfg))
    positions = jnp.arange(F)

    def layer_fn(x, lp):
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        q, k, v = L.attention_qkv(lp["attn"], h, a.n_heads, a.n_kv_heads,
                                  a.head_dim, positions, a.rope_theta)
        o = L.flash_attention_jnp(q, k, v, causal=False)
        x = x + o.reshape(B, F, -1) @ lp["attn"]["wo"]
        h = rms_norm(x, lp["ln2"], cfg.norm_eps)
        return x + L.mlp_apply(lp["mlp"], h, cfg.act), None

    x, _ = jax.lax.scan(layer_fn, x, params["enc_layers"])
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


def _cross_kv(params, cfg: ModelConfig, enc_out):
    """Per-decoder-layer cross K/V from encoder output: (L, B, F, Hkv, hd)."""
    a = cfg.attn
    B, F, D = enc_out.shape

    def one(lp):
        k = (enc_out @ lp["xattn"]["wk"]).reshape(B, F, a.n_kv_heads, a.head_dim)
        v = (enc_out @ lp["xattn"]["wv"]).reshape(B, F, a.n_kv_heads, a.head_dim)
        return k, v

    return jax.vmap(one)(params["dec_layers"])


class EncDecServeState(NamedTuple):
    self_kv: Any            # stacked (L, ...) WaveState or DenseCache
    cross_k: jax.Array      # (L, B, F, Hkv, hd)
    cross_v: jax.Array


def forward(params, cfg: ModelConfig, tokens, frames):
    """Teacher-forced decode over tokens with cross-attn to frames."""
    a = cfg.attn
    enc_out = encode(params, cfg, frames)
    ck, cv = _cross_kv(params, cfg, enc_out)
    x = params["embed"][tokens] * math.sqrt(cfg.d_model)
    B, T, D = x.shape
    positions = jnp.arange(T)

    @jax.checkpoint
    def layer_fn(x, xs):
        lp, k_x, v_x = xs
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        q, k, v = L.attention_qkv(lp["attn"], h, a.n_heads, a.n_kv_heads,
                                  a.head_dim, positions, a.rope_theta)
        o = L.flash_attention_jnp(q, k, v, causal=True)
        x = x + o.reshape(B, T, -1) @ lp["attn"]["wo"]
        h = rms_norm(x, lp["ln_x"], cfg.norm_eps)
        qx = (h @ lp["xattn"]["wq"]).reshape(B, T, a.n_heads, a.head_dim)
        ox = L.flash_attention_jnp(qx, k_x, v_x, causal=False)
        x = x + ox.reshape(B, T, -1) @ lp["xattn"]["wo"]
        h = rms_norm(x, lp["ln2"], cfg.norm_eps)
        return x + L.mlp_apply(lp["mlp"], h, cfg.act), None

    x, _ = jax.lax.scan(layer_fn, x, (params["dec_layers"], ck, cv))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, 0.0


def prefill(params, cfg: ModelConfig, tokens, frames, *, runtime="retro",
            plan: ZonePlan = None, gen_headroom: int = 4096, cache_len=None):
    a, retro = cfg.attn, cfg.retro
    B, T = tokens.shape
    if plan is None:
        plan = plan_zones(T, retro, gen_headroom)
    total = cache_len if cache_len is not None else T + gen_headroom
    enc_out = encode(params, cfg, frames)
    ck, cv = _cross_kv(params, cfg, enc_out)
    x = params["embed"][tokens] * math.sqrt(cfg.d_model)
    positions = jnp.arange(T)

    def layer_fn(x, xs):
        lp, k_x, v_x = xs
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        q, k, v = L.attention_qkv(lp["attn"], h, a.n_heads, a.n_kv_heads,
                                  a.head_dim, positions, a.rope_theta)
        o = L.flash_attention_jnp(q, k, v, causal=True)
        x = x + o.reshape(B, T, -1) @ lp["attn"]["wo"]
        h = rms_norm(x, lp["ln_x"], cfg.norm_eps)
        qx = (h @ lp["xattn"]["wq"]).reshape(B, T, a.n_heads, a.head_dim)
        ox = L.flash_attention_jnp(qx, k_x, v_x, causal=False)
        x = x + ox.reshape(B, T, -1) @ lp["xattn"]["wo"]
        h = rms_norm(x, lp["ln2"], cfg.norm_eps)
        x = x + L.mlp_apply(lp["mlp"], h, cfg.act)
        if runtime == "retro":
            st = prefill_build(k, v, retro, plan.m_max, dtype=_dtype(cfg))
        else:
            st = wa.DenseCache(
                jnp.swapaxes(jnp.pad(k, ((0, 0), (0, total - T),
                                         (0, 0), (0, 0))), 1, 2),
                jnp.swapaxes(jnp.pad(v, ((0, 0), (0, total - T),
                                         (0, 0), (0, 0))), 1, 2),
                jnp.full((B,), T, jnp.int32))
        return x, st

    x, kv = jax.lax.scan(layer_fn, x, (params["dec_layers"], ck, cv))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = (x[:, -1] @ params["embed"].T).astype(jnp.float32)
    return logits, EncDecServeState(self_kv=kv, cross_k=ck, cross_v=cv)


def decode_step(params, cfg: ModelConfig, state: EncDecServeState, token, *,
                runtime="retro", plan: ZonePlan, inline_flush: bool = False,
                active=None, attn_impl=None):
    a, retro = cfg.attn, cfg.retro
    impl = wa.resolve_attn_impl(attn_impl or retro.attn_impl)
    x = params["embed"][token] * math.sqrt(cfg.d_model)
    B = x.shape[0]

    def layer_fn(x, xs):
        lp, lstate, k_x, v_x = xs
        pos = lstate.length                                  # (B,) per-row
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        q, k, v = L.attention_qkv(lp["attn"], h[:, None, :], a.n_heads,
                                  a.n_kv_heads, a.head_dim,
                                  pos[:, None], a.rope_theta)
        q, k, v = q[:, 0], k[:, 0], v[:, 0]
        if runtime == "retro":
            lstate = append_token(lstate, k, v, active=active)
            o = wa.wave_attention_decode(q, lstate, retro, plan,
                                         impl=impl).out
            if inline_flush:
                lstate = maybe_flush(lstate, retro)
        else:
            lstate = wa.dense_cache_append(lstate, k, v, active=active)
            o = wa.full_attention_decode(q, lstate)
        x = x + o.reshape(B, -1) @ lp["attn"]["wo"]
        h = rms_norm(x, lp["ln_x"], cfg.norm_eps)
        qx = (h @ lp["xattn"]["wq"]).reshape(B, 1, a.n_heads, a.head_dim)
        ox = L.flash_attention_jnp(qx, k_x, v_x, causal=False)
        x = x + ox.reshape(B, -1) @ lp["xattn"]["wo"]
        h = rms_norm(x, lp["ln2"], cfg.norm_eps)
        return x + L.mlp_apply(lp["mlp"], h, cfg.act), lstate

    x, kv = jax.lax.scan(layer_fn, x, (params["dec_layers"], state.self_kv,
                                       state.cross_k, state.cross_v))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = (x @ params["embed"].T).astype(jnp.float32)
    return logits, EncDecServeState(self_kv=kv, cross_k=state.cross_k,
                                    cross_v=state.cross_v)


def init_serve_state(cfg: ModelConfig, B: int, seq_len: int, *,
                     runtime="retro", gen_headroom: int = 4096,
                     zero_fill: bool = False):
    a, retro = cfg.attn, cfg.retro
    plan = plan_zones(seq_len, retro, gen_headroom)
    F = cfg.encoder_frames

    def one(_):
        if runtime == "retro":
            st = init_wave_state(B, a.n_kv_heads, a.head_dim, plan.m_max,
                                 retro, _dtype(cfg))
            if not zero_fill:
                st = st._replace(
                    length=jnp.full((B,), seq_len, jnp.int32),
                    local_len=jnp.full((B,), retro.local, jnp.int32),
                    n_clusters=jnp.full((B,), plan.m_max, jnp.int32))
            return st
        return wa.DenseCache(
            jnp.zeros((B, a.n_kv_heads, seq_len + gen_headroom, a.head_dim),
                      _dtype(cfg)),
            jnp.zeros((B, a.n_kv_heads, seq_len + gen_headroom, a.head_dim),
                      _dtype(cfg)),
            jnp.full((B,), 0 if zero_fill else seq_len, jnp.int32))

    kv = jax.vmap(one)(jnp.arange(cfg.n_layers))
    L_ = cfg.n_layers
    return EncDecServeState(
        self_kv=kv,
        cross_k=jnp.zeros((L_, B, F, a.n_kv_heads, a.head_dim), _dtype(cfg)),
        cross_v=jnp.zeros((L_, B, F, a.n_kv_heads, a.head_dim), _dtype(cfg)))
