"""Unified GQA transformer: dense (gemma/minitron), MoE (mixtral/kimi), VLM
(llava backbone). Layers are weight-stacked and scanned (`lax.scan`) so the
compiled HLO stays compact at 61 layers x 512 devices.

Serve-time attention runtime is selectable:
  * "retro" — RetroInfer wave index (the paper's technique)
  * "full"  — dense KV cache, exact attention (the paper's baseline)
"""
from __future__ import annotations

import math
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import attention as wa
from repro.core.wave_index import (WaveState, append_token,
                                   init_chunked_prefill, init_wave_state,
                                   maybe_flush, prefill_append_chunk,
                                   prefill_build, prefill_finalize,
                                   scatter_chunk_rows)
from repro.core.zones import ZonePlan, plan_zones
from repro.models import layers as L
from repro.models.moe import init_moe, moe_apply_grouped

GLOBAL_WINDOW = 1.0e9   # "no sliding window" sentinel (traced-friendly)


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_layer(key, cfg: ModelConfig):
    a = cfg.attn
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "ln1": jnp.zeros((cfg.d_model,), _dtype(cfg)),
        "ln2": jnp.zeros((cfg.d_model,), _dtype(cfg)),
        "attn": L.init_attention(k1, cfg.d_model, a.n_heads, a.n_kv_heads,
                                 a.head_dim, _dtype(cfg)),
    }
    if cfg.moe is not None:
        p["moe"] = init_moe(k2, cfg.d_model, cfg.moe, _dtype(cfg))
    else:
        p["mlp"] = L.init_mlp(k3, cfg.d_model, cfg.d_ff, _dtype(cfg))
    return p


def init_transformer(cfg: ModelConfig, key) -> Dict[str, Any]:
    ks = jax.random.split(key, cfg.n_layers + 2)
    layers = jax.vmap(lambda k: init_layer(k, cfg))(ks[: cfg.n_layers])
    window = jnp.asarray(
        [cfg.attn.sliding_window if kind == "l" else GLOBAL_WINDOW
         for kind in cfg.layer_kinds()], jnp.float32)
    params = {
        "embed": L.dense_init(ks[-1], (cfg.vocab, cfg.d_model),
                              scale=cfg.d_model ** -0.5, dtype=_dtype(cfg)),
        "layers": layers,
        "window": window,
        "final_norm": jnp.zeros((cfg.d_model,), _dtype(cfg)),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.dense_init(ks[-2], (cfg.d_model, cfg.vocab),
                                         dtype=_dtype(cfg))
    return params


# ---------------------------------------------------------------------------
# shared pieces
# ---------------------------------------------------------------------------

def embed_tokens(params, cfg: ModelConfig, tokens, patch_embeds=None):
    x = params["embed"][tokens] * math.sqrt(cfg.d_model)
    if patch_embeds is not None:
        npatch = patch_embeds.shape[1]
        x = jnp.concatenate([patch_embeds.astype(x.dtype), x[:, npatch:]], axis=1)
    return x


def unembed(params, cfg: ModelConfig, x):
    if cfg.tie_embeddings:
        logits = x @ params["embed"].T
    else:
        logits = x @ params["lm_head"]
    return logits.astype(jnp.float32)


def _ffn(lp, x, cfg: ModelConfig):
    """x: (..., D) -> (..., D) plus aux loss scalar."""
    if cfg.moe is not None:
        shp = x.shape
        y, aux = moe_apply_grouped(lp["moe"], x.reshape(-1, shp[-1]), cfg.moe,
                                   cfg.act, groups=cfg.moe_dispatch_groups)
        return y.reshape(shp), aux
    return L.mlp_apply(lp["mlp"], x, cfg.act), 0.0


# ---------------------------------------------------------------------------
# training / scoring forward (full attention, chunked online-softmax)
# ---------------------------------------------------------------------------

def forward(params, cfg: ModelConfig, tokens, patch_embeds=None):
    """tokens: (B, T) -> hidden (B, T, D), aux_loss."""
    x = embed_tokens(params, cfg, tokens, patch_embeds)
    B, T, D = x.shape
    positions = jnp.arange(T)
    a = cfg.attn

    @jax.checkpoint
    def layer_fn(carry, xs):
        x, aux = carry
        lp, window = xs
        h = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
        q, k, v = L.attention_qkv(lp["attn"], h, a.n_heads, a.n_kv_heads,
                                  a.head_dim, positions, a.rope_theta)
        o = L.flash_attention_jnp(q, k, v, causal=True, window=window,
                                  softcap=a.softcap)
        x = x + o.reshape(B, T, -1) @ lp["attn"]["wo"]
        h = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
        y, aux_l = _ffn(lp, h, cfg)
        return (x + y, aux + aux_l), None

    (x, aux), _ = jax.lax.scan(layer_fn, (x, 0.0),
                               (params["layers"], params["window"]))
    return L.rms_norm(x, params["final_norm"], cfg.norm_eps), aux


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

class ServeState(NamedTuple):
    """Stacked per-layer KV state + scalar step info. kv is either a stacked
    WaveState (retro runtime) or stacked DenseCache (full runtime)."""
    kv: Any


def prefill(params, cfg: ModelConfig, tokens, patch_embeds=None, *,
            runtime: str = "retro", plan: Optional[ZonePlan] = None,
            gen_headroom: int = 4096, lengths: Optional[jax.Array] = None,
            cache_len: Optional[int] = None) -> Tuple[jax.Array, ServeState]:
    """Process the prompt; returns (last-position logits, serve state).

    ``lengths``: optional (B,) int32 true prompt lengths for right-padded
    ragged batches. Causality already keeps real queries blind to pad keys;
    the wave index masks pads out of its stores and the returned logits are
    taken at each row's own last real position.

    ``cache_len``: total dense-cache slots (full runtime) — continuous
    batching sizes every per-slot prefill to the engine-wide capacity so
    states graft into the shared decode batch.
    """
    x = embed_tokens(params, cfg, tokens, patch_embeds)
    B, T, D = x.shape
    positions = jnp.arange(T)
    a = cfg.attn
    retro = cfg.retro
    if plan is None:
        plan = plan_zones(T, retro, gen_headroom)
    lens = None if lengths is None else jnp.asarray(lengths, jnp.int32)
    total = cache_len if cache_len is not None else T + gen_headroom
    assert total >= T, (total, T)

    sp_blocks = cfg.sparse_prefill_blocks
    use_sparse = sp_blocks > 0 and T % 128 == 0

    def layer_fn(x, xs):
        lp, window = xs
        h = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
        q, k, v = L.attention_qkv(lp["attn"], h, a.n_heads, a.n_kv_heads,
                                  a.head_dim, positions, a.rope_theta)
        if use_sparse:
            from repro.core.sparse_prefill import block_sparse_attention
            o = block_sparse_attention(q, k, v, block=128,
                                       topk_blocks=sp_blocks, window=window,
                                       softcap=a.softcap)
        else:
            o = L.flash_attention_jnp(q, k, v, causal=True, window=window,
                                      softcap=a.softcap)
        x = x + o.reshape(B, T, -1) @ lp["attn"]["wo"]
        h = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
        y, _ = _ffn(lp, h, cfg)
        x = x + y
        if runtime == "retro":
            st = prefill_build(k, v, retro, plan.m_max, dtype=_dtype(cfg),
                               lengths=lens)
        else:
            st = wa.DenseCache(
                k=jnp.swapaxes(
                    jnp.pad(k, ((0, 0), (0, total - T), (0, 0), (0, 0))), 1, 2
                ).astype(_dtype(cfg)),
                v=jnp.swapaxes(
                    jnp.pad(v, ((0, 0), (0, total - T), (0, 0), (0, 0))), 1, 2
                ).astype(_dtype(cfg)),
                length=(jnp.full((B,), T, jnp.int32) if lens is None else lens))
        return x, st

    x, kv = jax.lax.scan(layer_fn, x, (params["layers"], params["window"]))
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    if lens is None:
        last = x[:, -1]
    else:
        last = jnp.take_along_axis(
            x, (lens - 1)[:, None, None].astype(jnp.int32), axis=1)[:, 0]
    logits = unembed(params, cfg, last)
    return logits, ServeState(kv=kv)


# ---------------------------------------------------------------------------
# Chunked prefill — admission interleaved with decode (serving engine).
#
# The prompt is consumed a fixed-size chunk at a time: chunk queries attend
# causally to the prior prompt prefix + the chunk itself via an exact
# admission-time dense cache, while the wave index is built incrementally by
# ``prefill_append_chunk``. One compiled shape serves every prompt length
# (the final chunk is right-padded and masked). The admission cache is
# dropped at finalize for the retro runtime; for the dense-cache runtime it
# IS the serve state. Chunk attention is exact — ``sparse_prefill_blocks``
# only applies to the monolithic prefill path.
# ---------------------------------------------------------------------------


class PrefillChunkState(NamedTuple):
    """Admission-time state for chunk-by-chunk prefill. Leaves are stacked
    per-layer (L, ...). ``cache`` holds the exact K/V of the prompt so far;
    ``wave`` is the streaming wave-index build (retro) or None (full)."""
    cache: Any              # stacked DenseCache
    wave: Any               # stacked ChunkedPrefill or None


def init_prefill_chunk_state(cfg: ModelConfig, B: int, max_ctx: int, *,
                             runtime: str = "retro", chunk: int,
                             gen_headroom: int = 4096) -> PrefillChunkState:
    """``max_ctx`` pins the admission geometry to the engine's decode state so
    the finalized state grafts into the shared batch. The dense-runtime cache
    is allocated at full decode capacity (it becomes the serve state); the
    retro admission cache only needs the prompt capacity."""
    a, retro = cfg.attn, cfg.retro
    plan = plan_zones(max_ctx, retro, gen_headroom)
    cache_len = max_ctx if runtime == "retro" else max_ctx + gen_headroom

    def one(_):
        cache = wa.DenseCache(
            jnp.zeros((B, a.n_kv_heads, cache_len, a.head_dim), _dtype(cfg)),
            jnp.zeros((B, a.n_kv_heads, cache_len, a.head_dim), _dtype(cfg)),
            jnp.zeros((B,), jnp.int32))
        if runtime == "retro":
            return cache, init_chunked_prefill(
                B, a.n_kv_heads, a.head_dim, plan.m_max, retro, chunk,
                _dtype(cfg))
        return cache, None

    cache, wave = jax.vmap(one)(jnp.arange(cfg.n_layers))
    return PrefillChunkState(cache=cache, wave=wave)


def _cache_append_chunk(cache: wa.DenseCache, k, v, clens) -> wa.DenseCache:
    """Append a (B, C, Hkv, hd) chunk at each row's cursor. Only the valid
    prefix of each row's chunk is written (dropped scatter — a padded final
    chunk near capacity must not clamp into earlier entries)."""
    B, C = k.shape[:2]
    cap = cache.k.shape[2]
    j = jnp.arange(C, dtype=jnp.int32)[None, :]
    clens = jnp.asarray(clens, jnp.int32)
    idx = jnp.where(j < clens[:, None], cache.length[:, None] + j, cap)
    return wa.DenseCache(
        scatter_chunk_rows(cache.k, jnp.swapaxes(k, 1, 2), idx),
        scatter_chunk_rows(cache.v, jnp.swapaxes(v, 1, 2), idx),
        cache.length + clens)


def _chunk_attention(q, cache: wa.DenseCache, t0, clens, *, window=None,
                     softcap=None):
    """Exact causal attention of chunk queries against the admission cache
    (which already holds the chunk). q: (B, C, Hq, hd); t0: (B,) absolute
    position of q[:, 0]; keys beyond each row's filled prefix are masked."""
    B, C, Hq, hd = q.shape
    Hkv = cache.k.shape[1]
    G = Hq // Hkv
    scale = 1.0 / math.sqrt(hd)
    qg = q.reshape(B, C, Hkv, G, hd)
    s = jnp.einsum("bchgd,bhtd->bhgct", qg.astype(jnp.float32),
                   cache.k.astype(jnp.float32)) * scale
    s = L.soft_cap(s, softcap)
    kpos = jnp.arange(cache.k.shape[2])
    q_abs = t0[:, None] + jnp.arange(C)                     # (B, C)
    ok = (kpos[None, None, :] <= q_abs[:, :, None]) \
        & (kpos[None, None, :] < (t0 + clens)[:, None, None])
    if window is not None:
        ok = ok & (kpos[None, None, :] > q_abs[:, :, None] - window)
    s = jnp.where(ok[:, None, None, :, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgct,bhtd->bhgcd", p, cache.v.astype(jnp.float32))
    return jnp.transpose(o, (0, 3, 1, 2, 4)).reshape(B, C, Hq, hd).astype(q.dtype)


def prefill_chunk(params, cfg: ModelConfig, tokens, state: PrefillChunkState,
                  *, runtime: str = "retro", chunk_lens=None,
                  patch_embeds=None) -> Tuple[jax.Array, PrefillChunkState]:
    """Process the next prompt chunk. tokens: (B, C) right-padded; returns
    (logits at each row's last valid chunk position, new state).

    ``chunk_lens``: optional (B,) valid prefix per row (None = full chunk).
    ``patch_embeds``: full (B, P, D) vlm patch embeddings — the slice
    overlapping this chunk's absolute positions replaces the token embeds.
    """
    a, retro = cfg.attn, cfg.retro
    B, C = tokens.shape
    clens = jnp.full((B,), C, jnp.int32) if chunk_lens is None \
        else jnp.asarray(chunk_lens, jnp.int32)
    t0 = state.cache.length[0]                              # (B,) shared by layers
    positions = t0[:, None] + jnp.arange(C)                 # (B, C) per-row
    x = params["embed"][tokens] * math.sqrt(cfg.d_model)
    if patch_embeds is not None:
        P = patch_embeds.shape[1]
        pe = jnp.take_along_axis(patch_embeds,
                                 jnp.clip(positions, 0, P - 1)[..., None],
                                 axis=1)
        x = jnp.where((positions < P)[..., None], pe.astype(x.dtype), x)

    def layer_fn(x, xs):
        lp, cache_l, wave_l, window = xs
        h = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
        q, k, v = L.attention_qkv(lp["attn"], h, a.n_heads, a.n_kv_heads,
                                  a.head_dim, positions, a.rope_theta)
        cache_l = _cache_append_chunk(cache_l, k, v, clens)
        o = _chunk_attention(q, cache_l, t0, clens, window=window,
                             softcap=a.softcap)
        x = x + o.reshape(B, C, -1) @ lp["attn"]["wo"]
        h = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
        y, _ = _ffn(lp, h, cfg)
        if runtime == "retro":
            wave_l = prefill_append_chunk(wave_l, k, v, retro, clens)
        return x + y, (cache_l, wave_l)

    x, (cache, wave) = jax.lax.scan(
        layer_fn, x,
        (params["layers"], state.cache, state.wave, params["window"]))
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    last = jnp.take_along_axis(
        x, jnp.maximum(clens - 1, 0)[:, None, None].astype(jnp.int32),
        axis=1)[:, 0]
    return unembed(params, cfg, last), PrefillChunkState(cache=cache, wave=wave)


def finalize_prefill_chunk(cfg: ModelConfig, state: PrefillChunkState, *,
                           runtime: str = "retro", total_len: int) -> ServeState:
    """Close a chunked admission: retro clusters the tail + installs the local
    window (bit-identical wave state to ``prefill_build``); the dense runtime's
    admission cache is the serve state as-is."""
    if runtime != "retro":
        return ServeState(kv=state.cache)
    kv = jax.vmap(
        lambda w: prefill_finalize(w, cfg.retro, total_len))(state.wave)
    return ServeState(kv=kv)


def decode_step(params, cfg: ModelConfig, state: ServeState, token, *,
                runtime: str = "retro", plan: ZonePlan,
                inline_flush: bool = False,
                active: Optional[jax.Array] = None,
                attn_impl: Optional[str] = None) -> Tuple[jax.Array, ServeState]:
    """One generation step. token: (B,) int32 -> logits (B, V).

    ``attn_impl``: wave-attention implementation — "jnp" (reference) or
    "fused" (gather-free paged Pallas kernel); None defers to
    ``cfg.retro.attn_impl``.

    ``inline_flush=False`` keeps the segmented-clustering index update OFF the
    hot path (the paper amortizes it to ~0.2% of decode latency by running it
    asynchronously every 1K tokens); the serving engine calls
    ``model.flush_state`` when the staging buffer fills. ``inline_flush=True``
    folds it into the step (self-contained, used by some tests).

    ``active``: optional (B,) bool continuous-batching slot mask — rows whose
    slot is free skip the KV append so their counters never drift; their
    logits are computed but discarded by the scheduler. Rows are at their OWN
    positions: RoPE uses each row's length."""
    a = cfg.attn
    retro = cfg.retro
    impl = wa.resolve_attn_impl(attn_impl or retro.attn_impl)
    x = params["embed"][token] * math.sqrt(cfg.d_model)     # (B, D)
    B = x.shape[0]

    def layer_fn(x, xs):
        lp, lstate, window = xs
        pos = lstate.length                                  # (B,) new token position
        h = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
        q, k, v = L.attention_qkv(
            lp["attn"], h[:, None, :], a.n_heads, a.n_kv_heads, a.head_dim,
            pos[:, None], a.rope_theta)
        q, k, v = q[:, 0], k[:, 0], v[:, 0]                  # (B, H*, hd)
        if runtime == "retro":
            lstate = append_token(lstate, k, v, active=active)
            out = wa.wave_attention_decode(q, lstate, retro, plan,
                                           window=window, softcap=a.softcap,
                                           impl=impl)
            if inline_flush:
                lstate = maybe_flush(lstate, retro)
            o = out.out
        else:
            lstate = wa.dense_cache_append(lstate, k, v, active=active)
            o = wa.full_attention_decode(q, lstate, window=window,
                                         softcap=a.softcap)
        x = x + o.reshape(B, -1) @ lp["attn"]["wo"]
        h = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
        y, _ = _ffn(lp, h, cfg)
        return x + y, lstate

    x, kv = jax.lax.scan(layer_fn, x,
                         (params["layers"], state.kv, params["window"]))
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return unembed(params, cfg, x), ServeState(kv=kv)


# ---------------------------------------------------------------------------
# Hot/cold state split (§Perf iteration 1, EXPERIMENTS.md)
#
# The monolithic serve step threads the full wave state through the layer
# scan; unchanged cluster stores then appear in the scan's ys and the step's
# outputs, which the compiled HLO materializes as full-store copies (and the
# cost analysis charges as memory traffic). Decode only MUTATES the staging
# buffers + counters ("hot"); the cluster stores/meta index ("cold") change
# only at the 1K-token flush. Splitting them keeps the cold stores out of the
# step's dataflow entirely.
# ---------------------------------------------------------------------------

COLD_FIELDS = ("k_store", "v_store", "pos_store", "centroid", "vsum", "size",
               "stored", "max_pos", "n_clusters")
HOT_FIELDS = ("sink_k", "sink_v", "local_k", "local_v", "local_len", "length")


def split_state(kv: WaveState):
    cold = {f: getattr(kv, f) for f in COLD_FIELDS}
    hot = {f: getattr(kv, f) for f in HOT_FIELDS}
    return cold, hot


def join_state(cold, hot) -> WaveState:
    return WaveState(**cold, **hot)


def decode_step_split(params, cfg: ModelConfig, cold, hot, token, *,
                      plan: ZonePlan, unroll: bool = False, mesh=None,
                      attn_impl: Optional[str] = None):
    """Retro decode with the hot/cold split: returns (logits, new_hot).

    ``cold``/``hot`` are dicts of stacked (L, ...) leaves as produced by
    ``split_state`` applied to ``ServeState.kv``.

    ``unroll=True`` replaces the layer scan with an unrolled loop (§Perf
    iteration): lax.scan bundles its xs — including the read-only cluster
    stores — into the while-loop tuple, which buffer assignment materializes
    as a full-store temp copy; unrolling reads the stores in place.

    ``attn_impl``: as in ``decode_step`` ("fused" composes with the split:
    the paged kernel reads the cold stores in place)."""
    a, retro = cfg.attn, cfg.retro
    impl = wa.resolve_attn_impl(attn_impl or retro.attn_impl)
    x = params["embed"][token] * math.sqrt(cfg.d_model)
    B = x.shape[0]

    def layer_fn(x, xs):
        lp, cold_i, hot_i, window = xs
        lstate = join_state(cold_i, hot_i)
        pos = lstate.length                                  # (B,) per-row
        h = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
        q, k, v = L.attention_qkv(
            lp["attn"], h[:, None, :], a.n_heads, a.n_kv_heads, a.head_dim,
            pos[:, None], a.rope_theta)
        q, k, v = q[:, 0], k[:, 0], v[:, 0]
        lstate = append_token(lstate, k, v)
        if mesh is not None:
            from repro.core.distributed import distributed_wave_attention
            o = distributed_wave_attention(q, lstate, retro, plan, mesh,
                                           window=window, softcap=a.softcap)
        else:
            o = wa.wave_attention_decode(q, lstate, retro, plan,
                                         window=window, softcap=a.softcap,
                                         impl=impl).out
        x = x + o.reshape(B, -1) @ lp["attn"]["wo"]
        h = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
        y, _ = _ffn(lp, h, cfg)
        new_hot = {f: getattr(lstate, f) for f in HOT_FIELDS}
        return x + y, new_hot

    if unroll:
        # cold may be a stacked dict of (L, ...) leaves or a per-layer list
        # (separate args => no per-layer slices of the stacked store, which
        # the HLO cost model charges at full-operand size; see EXPERIMENTS).
        per_layer_cold = isinstance(cold, (list, tuple))
        hots = []
        kinds = cfg.layer_kinds()
        for i in range(cfg.n_layers):
            sl = lambda t: jax.tree.map(lambda a_: a_[i], t)
            cold_i = cold[i] if per_layer_cold else sl(cold)
            # static per-layer window in the unrolled path
            win = jnp.float32(a.sliding_window if kinds[i] == "l"
                              else GLOBAL_WINDOW)
            x, nh = layer_fn(x, (sl(params["layers"]), cold_i, sl(hot), win))
            hots.append(nh)
        new_hot = jax.tree.map(lambda *xs: jnp.stack(xs), *hots)
    else:
        assert mesh is None, "distributed retrieval requires unroll=True"
        x, new_hot = jax.lax.scan(
            layer_fn, x, (params["layers"], cold, hot, params["window"]))
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return unembed(params, cfg, x), new_hot


# ---------------------------------------------------------------------------
# Host-offload decode (paper Sec. 4.3: wave buffer in the serve loop)
#
# The cluster PAYLOAD stores (k_store/v_store/pos_store) live host-side; the
# device keeps the meta index + steady zones ("live" fields) plus a block
# cache. One decode step is two jitted halves per layer with the control
# plane (cluster-id -> cache-slot translation, miss fetch, deferred
# admissions) in between:
#
#   rank:   qkv + local append + centroid ranking + estimation build
#           -> retrieved cluster ids (the only per-layer host sync)
#   attend: paged attention over [device block cache | miss staging buffer]
#           via translated cache slots, then output proj + FFN
#
# Identical math to ``decode_step`` — cache placement is accuracy-agnostic.
# ---------------------------------------------------------------------------

PAYLOAD_FIELDS = ("k_store", "v_store", "pos_store")
LIVE_FIELDS = tuple(f for f in WaveState._fields if f not in PAYLOAD_FIELDS)


def live_wave_state(live: Dict[str, jax.Array]) -> WaveState:
    """WaveState view over the device-resident fields of the host-offload
    configuration — the payload stores are host-side, so they are ``None``
    here; rank/estimation/steady-zone code never touches them."""
    return WaveState(k_store=None, v_store=None, pos_store=None, **live)


def decode_embed(params, cfg: ModelConfig, token):
    """token: (B,) int32 -> (B, D) embedded decode input."""
    return params["embed"][token] * math.sqrt(cfg.d_model)


def decode_unembed(params, cfg: ModelConfig, x):
    """(B, D) final hidden -> (B, V) logits (final norm + unembed)."""
    return unembed(params, cfg, L.rms_norm(x, params["final_norm"],
                                           cfg.norm_eps))


def offload_decode_rank(lp, window, cfg: ModelConfig, live: Dict, x, *,
                        plan: ZonePlan, active: Optional[jax.Array] = None):
    """Control-plane half of one offload decode layer. Returns
    ``(ctx, idx_r, new_live)`` — ``idx_r`` (B, Hkv, r) are the retrieved
    cluster ids the engine translates into cache slots; ``ctx`` carries the
    query + estimation tensors into :func:`offload_decode_attend`."""
    a, retro = cfg.attn, cfg.retro
    B = x.shape[0]
    lstate = live_wave_state(live)
    pos = lstate.length                                  # (B,) new token pos
    h = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
    q, k, v = L.attention_qkv(
        lp["attn"], h[:, None, :], a.n_heads, a.n_kv_heads, a.head_dim,
        pos[:, None], a.rope_theta)
    q, k, v = q[:, 0], k[:, 0], v[:, 0]                  # (B, H*, hd)
    lstate = append_token(lstate, k, v, active=active)
    G = a.n_heads // a.n_kv_heads
    qg = q.reshape(B, a.n_kv_heads, G, a.head_dim)
    idx_r, est_logit, cs_e, vs_e, cover = wa.wave_decode_rank(
        qg, lstate, retro, plan, window=window, softcap=a.softcap,
        with_cover=True)
    ctx = (q, est_logit, cs_e, vs_e, cover)
    return ctx, idx_r, {f: getattr(lstate, f) for f in LIVE_FIELDS}


def offload_decode_attend(lp, window, cfg: ModelConfig, live: Dict, x, ctx,
                          cache_k, cache_v, cache_pos, idx_slots, valid, *,
                          plan: ZonePlan, attn_impl: Optional[str] = None):
    """Data-plane half: attention over the steady zone + the slot-addressed
    blocks of the device cache (hits) / miss staging tail (misses), then
    output projection + FFN. Returns the next hidden state.

    ``valid``: (B, Hkv, r) int32 per-cluster validity mask from the control
    plane's translate — 0 marks a cluster whose fetch failed its deadline
    this step; it is masked out of the retrieval zone and covered by the
    estimation zone via ``ctx``'s cover triple (degraded decode). All-ones
    is bit-identical to the pre-fault path."""
    a, retro = cfg.attn, cfg.retro
    impl = wa.resolve_attn_impl(attn_impl or retro.attn_impl)
    B = x.shape[0]
    lstate = live_wave_state(live)
    q, est_logit, cs_e, vs_e, cover = ctx
    out = wa.wave_attention_attend(
        q, lstate, retro, plan, idx_slots, est_logit, cs_e, vs_e,
        kv_src=(cache_k, cache_v, cache_pos), window=window,
        softcap=a.softcap, impl=impl, valid=valid, cover=cover).out
    x = x + out.reshape(B, -1) @ lp["attn"]["wo"]
    h = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
    y, _ = _ffn(lp, h, cfg)
    return x + y


def offload_flush(cfg: ModelConfig, live_stacked: Dict, rows):
    """Index update for the offload serve path: per layer, cluster the oldest
    update segment into META entries on device and return the payload blocks
    (stacked (L, B, H, k_new, cap, ...)) for the host store. ``rows``: (B,)
    bool — rows to flush (the engine's staging-full mirror); unflushed rows
    pass through bit-unchanged and their returned blocks must be ignored."""
    from repro.core.wave_index import flush_segment_offload

    def one(lv):
        st, res = flush_segment_offload(live_wave_state(lv), cfg.retro,
                                        rows=rows)
        return {f: getattr(st, f) for f in LIVE_FIELDS}, res

    return jax.vmap(one)(live_stacked)


def init_serve_state(cfg: ModelConfig, B: int, seq_len: int, *,
                     runtime: str = "retro", gen_headroom: int = 4096,
                     zero_fill: bool = False) -> ServeState:
    """Zero-initialized serve state with the same structure/shape the prefill
    produces — used for dry-run lowering of serve_step without a real prefill.

    ``zero_fill=True`` leaves every per-row counter at zero (an all-free
    continuous-batching batch awaiting per-slot prefill grafts) instead of
    pretending each row holds a full ``seq_len`` context."""
    a, retro = cfg.attn, cfg.retro
    plan = plan_zones(seq_len, retro, gen_headroom)

    def one_layer(_):
        if runtime == "retro":
            st = init_wave_state(B, a.n_kv_heads, a.head_dim, plan.m_max,
                                 retro, _dtype(cfg))
            if not zero_fill:
                st = st._replace(
                    length=jnp.full((B,), seq_len, jnp.int32),
                    local_len=jnp.full((B,), retro.local, jnp.int32),
                    n_clusters=jnp.full((B,), plan.m_max, jnp.int32))
            return st
        return wa.DenseCache(
            jnp.zeros((B, a.n_kv_heads, seq_len + gen_headroom, a.head_dim),
                      _dtype(cfg)),
            jnp.zeros((B, a.n_kv_heads, seq_len + gen_headroom, a.head_dim),
                      _dtype(cfg)),
            jnp.full((B,), 0 if zero_fill else seq_len, jnp.int32))

    kv = jax.vmap(one_layer)(jnp.arange(cfg.n_layers))
    return ServeState(kv=kv)
