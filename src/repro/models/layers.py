"""Common functional layers: norms, RoPE, GQA attention, gated MLP.

Pure-functional style: ``init_*`` returns a pytree of parameters, ``*_apply``
consumes it. No flax/haiku — parameters are plain nested dicts so they shard
cleanly under pjit and stack cleanly for ``lax.scan`` over layers.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key, shape, scale: Optional[float] = None, dtype=jnp.float32):
    fan_in = shape[0]
    scale = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def rms_norm(x, gamma, eps: float = 1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    normed = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (normed * (1.0 + gamma.astype(jnp.float32))).astype(x.dtype)


def layer_norm(x, gamma, beta, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps) * gamma + beta).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float = 10_000.0):
    """x: (..., T, H, hd); positions: broadcastable to (..., T)."""
    hd = x.shape[-1]
    inv = rope_frequencies(hd, theta)                       # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * inv    # (..., T, hd/2)
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    sin = sin[..., None, :]                                 # (..., T, 1, hd/2)
    cos = cos[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(length: int, dim: int):
    pos = jnp.arange(length, dtype=jnp.float32)[:, None]
    div = jnp.exp(jnp.arange(0, dim, 2, dtype=jnp.float32) * (-math.log(10000.0) / dim))
    pe = jnp.zeros((length, dim), dtype=jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
    return pe


# ---------------------------------------------------------------------------
# Attention (full, chunked online-softmax) — training / prefill path
# ---------------------------------------------------------------------------

def soft_cap(scores, cap: Optional[float]):
    if cap is None or cap <= 0:
        return scores
    return cap * jnp.tanh(scores / cap)


def _repeat_kv(k, n_rep: int):
    """(B, T, Hkv, d) -> (B, T, Hkv*n_rep, d)."""
    if n_rep == 1:
        return k
    b, t, h, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, t, h, n_rep, d)).reshape(b, t, h * n_rep, d)


def flash_attention_jnp(q, k, v, *, causal: bool = True, window: Optional[jax.Array] = None,
                        softcap: Optional[float] = None, q_offset=0, block: int = 1024):
    """Chunked online-softmax attention in pure jnp (memory O(T*block)).

    q: (B, Tq, Hq, d); k,v: (B, Tk, Hkv, d). GQA handled by head repetition.
    ``window``: scalar (may be traced) sliding-window width; None => global.
    ``q_offset``: absolute position of q[0] (for decode / cross-chunk masks).
    Returns (B, Tq, Hq, d).
    """
    b, tq, hq, d = q.shape
    tk, hkv = k.shape[1], k.shape[2]
    n_rep = hq // hkv
    k = _repeat_kv(k, n_rep)
    v = _repeat_kv(v, n_rep)
    scale = 1.0 / math.sqrt(d)
    qf = q.astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)

    nblk = max(1, (tk + block - 1) // block)
    pad = nblk * block - tk
    if pad:
        kf = jnp.pad(kf, ((0, 0), (0, pad), (0, 0), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kf = kf.reshape(b, nblk, block, hq, d)
    vf = vf.reshape(b, nblk, block, hq, d)

    q_pos = q_offset + jnp.arange(tq)

    def body(carry, blk):
        m, l, acc = carry
        kb, vb, j0 = blk
        s = jnp.einsum("bqhd,bkhd->bhqk", qf, kb)
        s = soft_cap(s, softcap)
        k_pos = j0 + jnp.arange(block)
        valid = (k_pos < tk)[None, None, None, :]
        if causal:
            valid = valid & (k_pos[None, :] <= q_pos[:, None])[None, None]
        if window is not None:
            valid = valid & (k_pos[None, :] > q_pos[:, None] - window)[None, None]
        s = jnp.where(valid, s, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(valid, p, 0.0)
        corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum("bhqk,bkhd->bhqd", p, vb)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, hq, tq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, hq, tq), jnp.float32)
    a0 = jnp.zeros((b, hq, tq, d), jnp.float32)
    offs = jnp.arange(nblk) * block
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0),
        (jnp.moveaxis(kf, 1, 0), jnp.moveaxis(vf, 1, 0), offs))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return jnp.transpose(out, (0, 2, 1, 3)).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA attention block params
# ---------------------------------------------------------------------------

def init_attention(key, d_model: int, n_heads: int, n_kv: int, head_dim: int, dtype):
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], (d_model, n_heads * head_dim), dtype=dtype),
        "wk": dense_init(ks[1], (d_model, n_kv * head_dim), dtype=dtype),
        "wv": dense_init(ks[2], (d_model, n_kv * head_dim), dtype=dtype),
        "wo": dense_init(ks[3], (n_heads * head_dim, d_model), dtype=dtype),
    }


def attention_qkv(p, x, n_heads: int, n_kv: int, head_dim: int, positions, theta: float):
    """Project + rope. x: (B, T, D) -> q (B,T,Hq,hd), k,v (B,T,Hkv,hd)."""
    b, t, _ = x.shape
    q = (x @ p["wq"]).reshape(b, t, n_heads, head_dim)
    k = (x @ p["wk"]).reshape(b, t, n_kv, head_dim)
    v = (x @ p["wv"]).reshape(b, t, n_kv, head_dim)
    q = apply_rope(q, positions, theta)
    k = apply_rope(k, positions, theta)
    return q, k, v


def init_mlp(key, d_model: int, d_ff: int, dtype):
    ks = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(ks[0], (d_model, d_ff), dtype=dtype),
        "w_up": dense_init(ks[1], (d_model, d_ff), dtype=dtype),
        "w_down": dense_init(ks[2], (d_ff, d_model), dtype=dtype),
    }


def mlp_apply(p, x, act: str = "silu"):
    g = x @ p["w_gate"]
    g = jax.nn.silu(g) if act == "silu" else jax.nn.gelu(g, approximate=True)
    return (g * (x @ p["w_up"])) @ p["w_down"]
