"""Scan helpers: rematerialized chunked time-scans.

Recurrent (RWKV/Mamba) training scans save per-step residuals for backward —
O(T · state) memory. Chunking the scan and checkpointing each chunk bounds the
peak at O(chunk · state + T/chunk · carry), the standard recompute trade.
"""
from __future__ import annotations

import jax


def remat_chunked_scan(body, carry, xs, chunk: int = 256):
    """Drop-in for ``lax.scan(body, carry, xs)`` with per-chunk remat."""
    T = jax.tree.leaves(xs)[0].shape[0]
    if T % chunk != 0 or T <= chunk:
        return jax.lax.scan(body, carry, xs)
    n = T // chunk
    xs_c = jax.tree.map(lambda a: a.reshape((n, chunk) + a.shape[1:]), xs)

    @jax.checkpoint
    def outer(c, xc):
        return jax.lax.scan(body, c, xc)

    carry, ys = jax.lax.scan(outer, carry, xs_c)
    ys = jax.tree.map(lambda a: a.reshape((T,) + a.shape[2:]), ys)
    return carry, ys
