"""RWKV-6 "Finch" (arXiv:2404.05892): attention-free token mixing with
data-dependent decay. Time-mix keeps a per-head (hd x hd) matrix state with
per-channel decay w_t computed from the input (the architecture's signature
feature); channel-mix is a squared-ReLU FFN.

The wave index is *inapplicable* here (no KV cache exists) — recorded in
DESIGN §Arch-applicability. Decode is O(1) per token by construction, which
is why this arch runs long_500k natively.
"""
from __future__ import annotations

import math
from typing import Any, Dict, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init, rms_norm
from repro.models.scan_utils import remat_chunked_scan

LORA_RANK = 32


def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


class RwkvLayerState(NamedTuple):
    wkv: jax.Array          # (B, H, hd, hd) matrix state
    x_tm: jax.Array         # (B, D) previous input (time-mix token shift)
    x_cm: jax.Array         # (B, D) previous input (channel-mix token shift)


def init_layer(key, cfg: ModelConfig):
    D, F = cfg.d_model, cfg.d_ff
    hd = cfg.ssm.head_dim
    H = D // hd
    ks = jax.random.split(key, 16)
    dt = _dtype(cfg)
    return {
        "ln1": jnp.zeros((D,), dt), "ln2": jnp.zeros((D,), dt),
        # data-dependent token-shift mixing (5 targets: r,k,v,g,w)
        "mu_x": jnp.full((D,), 0.5, dt),
        "mu": jnp.full((5, D), 0.5, dt),
        "lora_a": dense_init(ks[0], (D, 5 * LORA_RANK), dtype=dt),
        "lora_b": (jax.random.normal(ks[1], (5, LORA_RANK, D)) * 0.01).astype(dt),
        # projections
        "wr": dense_init(ks[2], (D, D), dtype=dt),
        "wk": dense_init(ks[3], (D, D), dtype=dt),
        "wv": dense_init(ks[4], (D, D), dtype=dt),
        "wg": dense_init(ks[5], (D, D), dtype=dt),
        "wo": dense_init(ks[6], (D, D), dtype=dt),
        # data-dependent decay
        "w0": jnp.full((D,), -6.0, dt),
        "wd_a": dense_init(ks[7], (D, LORA_RANK), dtype=dt),
        "wd_b": (jax.random.normal(ks[8], (LORA_RANK, D)) * 0.01).astype(dt),
        "u": (jax.random.normal(ks[9], (D,)) * 0.1).astype(dt),   # bonus
        "gn": jnp.ones((H, hd), dt),                               # group norm
        # channel mix
        "mu_ck": jnp.full((D,), 0.5, dt), "mu_cr": jnp.full((D,), 0.5, dt),
        "ck": dense_init(ks[10], (D, F), dtype=dt),
        "cv": dense_init(ks[11], (F, D), dtype=dt),
        "cr": dense_init(ks[12], (D, D), dtype=dt),
    }


def init_rwkv6(cfg: ModelConfig, key) -> Dict[str, Any]:
    ks = jax.random.split(key, cfg.n_layers + 1)
    layers = jax.vmap(lambda k: init_layer(k, cfg))(ks[:-1])
    return {
        "embed": dense_init(ks[-1], (cfg.vocab, cfg.d_model), scale=cfg.d_model ** -0.5,
                            dtype=_dtype(cfg)),
        "layers": layers,
        "final_norm": jnp.zeros((cfg.d_model,), _dtype(cfg)),
    }


def _ddlerp(lp, x, x_prev):
    """Data-dependent token-shift: returns (5, ..., D) mixed inputs."""
    xx = x_prev - x
    base = x + xx * lp["mu_x"]
    feat = jnp.tanh(base @ lp["lora_a"])                   # (..., 5*rank)
    feat = feat.reshape(feat.shape[:-1] + (5, LORA_RANK))
    off = jnp.einsum("...fr,frd->f...d", feat, lp["lora_b"].astype(jnp.float32))
    mu = lp["mu"].reshape((5,) + (1,) * (x.ndim - 1) + (x.shape[-1],))
    return x[None] + xx[None] * (mu + off.astype(x.dtype))


def _decay(lp, xw):
    """Per-channel decay in (0, 1): exp(-exp(w0 + lora(xw)))."""
    loraw = jnp.tanh(xw @ lp["wd_a"]) @ lp["wd_b"]
    return jnp.exp(-jnp.exp((lp["w0"] + loraw).astype(jnp.float32)))


def _time_mix_step(lp, H, hd, x, x_prev, S):
    """One token. x: (B, D); S: (B, H, hd, hd). Returns (out, new_S)."""
    B, D = x.shape
    mixed = _ddlerp(lp, x, x_prev)                         # (5, B, D)
    xr, xk, xv, xg, xw = mixed
    r = (xr @ lp["wr"]).reshape(B, H, hd).astype(jnp.float32)
    k = (xk @ lp["wk"]).reshape(B, H, hd).astype(jnp.float32)
    v = (xv @ lp["wv"]).reshape(B, H, hd).astype(jnp.float32)
    g = jax.nn.silu(xg @ lp["wg"])
    w = _decay(lp, xw).reshape(B, H, hd)                   # (B, H, hd)
    u = lp["u"].astype(jnp.float32).reshape(H, hd)

    a = jnp.einsum("bhi,bhj->bhij", k, v)                  # outer product
    out = jnp.einsum("bhi,bhij->bhj", r, S + u[None, :, :, None] * a)
    S_new = w[..., None] * S + a
    # per-head group norm
    var = jnp.mean(jnp.square(out), axis=-1, keepdims=True)
    out = out * jax.lax.rsqrt(var + 1e-6) * lp["gn"].astype(jnp.float32)[None]
    out = out.reshape(B, H * hd).astype(x.dtype) * g
    return out @ lp["wo"], S_new


def _channel_mix(lp, x, x_prev):
    xk = x + (x_prev - x) * lp["mu_ck"]
    xr = x + (x_prev - x) * lp["mu_cr"]
    k = jnp.square(jax.nn.relu(xk @ lp["ck"]))
    return (k @ lp["cv"]) * jax.nn.sigmoid(xr @ lp["cr"])


def layer_apply_seq(lp, cfg: ModelConfig, x):
    """Training path: scan over time. x: (B, T, D) -> (B, T, D)."""
    B, T, D = x.shape
    hd = cfg.ssm.head_dim
    H = D // hd
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    h_prev = jnp.pad(h, ((0, 0), (1, 0), (0, 0)))[:, :-1]

    def step(S, inp):
        xt, xp = inp
        out, S = _time_mix_step(lp, H, hd, xt, xp, S)
        return S, out

    S0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    _, tm_out = remat_chunked_scan(step, S0, (jnp.swapaxes(h, 0, 1),
                                              jnp.swapaxes(h_prev, 0, 1)))
    x = x + jnp.swapaxes(tm_out, 0, 1)

    h = rms_norm(x, lp["ln2"], cfg.norm_eps)
    h_prev = jnp.pad(h, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    return x + _channel_mix(lp, h, h_prev)


def forward(params, cfg: ModelConfig, tokens):
    x = params["embed"][tokens] * math.sqrt(cfg.d_model)

    @jax.checkpoint
    def layer_fn(x, lp):
        return layer_apply_seq(lp, cfg, x), None

    x, _ = jax.lax.scan(layer_fn, x, params["layers"])
    return rms_norm(x, params["final_norm"], cfg.norm_eps), 0.0


# ---------------------------------------------------------------------------
# serving: O(1) recurrent state
# ---------------------------------------------------------------------------

def init_serve_state(cfg: ModelConfig, B: int):
    hd = cfg.ssm.head_dim
    H = cfg.d_model // hd

    def one(_):
        return RwkvLayerState(
            wkv=jnp.zeros((B, H, hd, hd), jnp.float32),
            x_tm=jnp.zeros((B, cfg.d_model), _dtype(cfg)),
            x_cm=jnp.zeros((B, cfg.d_model), _dtype(cfg)))

    return jax.vmap(one)(jnp.arange(cfg.n_layers))


def decode_step(params, cfg: ModelConfig, state, token):
    """token: (B,) -> (logits, new_state)."""
    x = params["embed"][token] * math.sqrt(cfg.d_model)
    hd = cfg.ssm.head_dim
    H = cfg.d_model // hd

    def layer_fn(x, xs):
        lp, st = xs
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        out, S = _time_mix_step(lp, H, hd, h, st.x_tm, st.wkv)
        x = x + out
        h2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
        x = x + _channel_mix(lp, h2, st.x_cm)
        return x, RwkvLayerState(wkv=S, x_tm=h, x_cm=h2)

    x, new_state = jax.lax.scan(layer_fn, x, (params["layers"], state))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return (x @ params["embed"].T).astype(jnp.float32), new_state


def prefill(params, cfg: ModelConfig, tokens):
    """Prompt processing via the sequential path, returning the serve state."""
    B, T = tokens.shape
    x = params["embed"][tokens] * math.sqrt(cfg.d_model)
    hd = cfg.ssm.head_dim
    H = cfg.d_model // hd

    def layer_fn(x, lp):
        B, T, D = x.shape
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        h_prev = jnp.pad(h, ((0, 0), (1, 0), (0, 0)))[:, :-1]

        def step(S, inp):
            xt, xp = inp
            out, S = _time_mix_step(lp, H, hd, xt, xp, S)
            return S, out

        S0 = jnp.zeros((B, H, hd, hd), jnp.float32)
        S, tm_out = jax.lax.scan(step, S0, (jnp.swapaxes(h, 0, 1),
                                            jnp.swapaxes(h_prev, 0, 1)))
        x = x + jnp.swapaxes(tm_out, 0, 1)
        h2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
        h2_prev = jnp.pad(h2, ((0, 0), (1, 0), (0, 0)))[:, :-1]
        x = x + _channel_mix(lp, h2, h2_prev)
        st = RwkvLayerState(wkv=S, x_tm=h[:, -1], x_cm=h2[:, -1])
        return x, st

    x, state = jax.lax.scan(layer_fn, x, params["layers"])
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return (x[:, -1] @ params["embed"].T).astype(jnp.float32), state
