"""Training launcher.

Single-host (real devices) training on synthetic data with checkpointing:

    PYTHONPATH=src python -m repro.launch.train --arch gemma2_2b --reduced \
        --steps 100 --batch 8 --seq 256 --ckpt /tmp/ckpt

On a real TPU cluster the same step function is pjit'd with the sharding
rules from ``repro.launch.sharding`` (exactly what dryrun.py lowers).
"""
from __future__ import annotations

import argparse
import json

import jax

from repro.configs.registry import get_config, reduced_config
from repro.data.pipeline import lm_batches
from repro.training import checkpoint as ckpt
from repro.training.optimizer import AdamWConfig
from repro.training.train_loop import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale variant (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    data = lm_batches(cfg, args.batch, args.seq, seed=args.seed)
    opt = AdamWConfig(lr=args.lr, warmup_steps=max(2, args.steps // 20),
                      total_steps=args.steps)

    def log(step, m):
        print(json.dumps({"step": step, **m}), flush=True)

    state, history = train(cfg, opt, data, args.steps,
                           key=jax.random.PRNGKey(args.seed), callback=log)
    if args.ckpt:
        ckpt.save(args.ckpt, state, step=args.steps,
                  meta={"arch": cfg.arch_id})
        print(f"checkpoint saved to {args.ckpt}")
    print(f"final loss: {history[-1]['loss']:.4f} "
          f"(from {history[0]['loss']:.4f})")


if __name__ == "__main__":
    main()
