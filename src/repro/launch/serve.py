"""Serving launcher: continuous-batching demo with the wave-index runtime.

Ragged prompt lengths and staggered generation lengths exercise the slot
scheduler: finished requests free their slot mid-stream and queued requests
are admitted mid-stream — by default one fixed-size prefill chunk at a time,
interleaved between decode steps (``--admission blocking`` restores the
monolithic per-slot prefill for comparison; inter-token p50/p99 shows the
admission interference each mode leaves behind).

    PYTHONPATH=src python -m repro.launch.serve --arch gemma2_2b --reduced \
        --requests 6 --batch 2 --prompt-lens 640,512,700 --new-tokens 16 \
        --stagger 8 --admission chunked --prefill-chunk 128
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs.registry import get_config, reduced_config
from repro.models import model as M
from repro.serving.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--runtime", default="retro", choices=["retro", "full"])
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-lens", default="640",
                    help="comma-separated lengths, cycled over the queue")
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--stagger", type=int, default=0,
                    help="request i generates new-tokens + i*stagger tokens")
    ap.add_argument("--admission", default="chunked",
                    choices=["chunked", "blocking"])
    ap.add_argument("--attn-impl", default=None, choices=["jnp", "fused"],
                    help="retro decode-attention implementation: 'jnp' "
                         "(reference execution-buffer path) or 'fused' "
                         "(gather-free paged Pallas wave-attention kernel — "
                         "retrieved clusters read from the stores in place, "
                         "no gather temp; interpret-mode on CPU). Default: "
                         "the config's retro.attn_impl")
    ap.add_argument("--prefill-chunk", type=int, default=256,
                    help="chunked-admission tokens per scheduler iteration")
    ap.add_argument("--prefill-bucket", type=int, default=1,
                    help="blocking-mode prompt-length bucket")
    ap.add_argument("--offload", action="store_true",
                    help="host-offload wave buffer (paper Sec. 4.3): cluster "
                         "payload stores live host-side; decode retrieval "
                         "goes through a device block cache with cache-slot "
                         "indirection into the paged kernel. Token-for-token "
                         "identical to the direct-store path; requires the "
                         "retro runtime on an attention family")
    ap.add_argument("--cache-frac", type=float, default=None,
                    help="device block-cache size as a fraction of the "
                         "cluster store (offload mode; clamped >= 1 slot). "
                         "Default: the config's retro.cache_frac")
    ap.add_argument("--cache-policy", default=None,
                    choices=["lru", "fifo", "clock"],
                    help="block-cache replacement policy (offload mode)")
    ap.add_argument("--fault-profile", default=None,
                    help="retrofault: inject link faults into the offload "
                         "miss-fetch path, e.g. "
                         "'transient=0.2,corrupt=0.01,spike=0.1,seed=3' "
                         "(seed-deterministic; rates are per-attempt "
                         "probabilities). Failed fetches are masked out of "
                         "the retrieval zone and covered by the estimation "
                         "zone (degraded decode)")
    ap.add_argument("--fetch-deadline", type=float, default=None,
                    help="per-translate-call virtual fetch budget in "
                         "seconds; overdue misses degrade instead of "
                         "stalling the step")
    ap.add_argument("--fetch-retries", type=int, default=2,
                    help="bounded retries per miss fetch (exponential "
                         "virtual backoff)")
    ap.add_argument("--max-decode-steps", type=int, default=None,
                    help="per-request watchdog: finish a request with "
                         "status='timeout' after this many decode steps")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    params = M.init_params(cfg, jax.random.PRNGKey(args.seed))
    lens = [int(x) for x in args.prompt_lens.split(",")]
    engine = ServeEngine(cfg, params, runtime=args.runtime, gen_headroom=512,
                         admission=args.admission,
                         prefill_chunk=args.prefill_chunk,
                         prefill_bucket=args.prefill_bucket,
                         attn_impl=args.attn_impl, offload=args.offload,
                         cache_frac=args.cache_frac,
                         cache_policy=args.cache_policy,
                         fault_profile=args.fault_profile,
                         fetch_deadline_s=args.fetch_deadline,
                         fetch_retries=args.fetch_retries,
                         max_decode_steps=args.max_decode_steps)
    rng = np.random.default_rng(args.seed)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab, lens[i % len(lens)])
                    .astype(np.int32),
                    max_new_tokens=args.new_tokens + i * args.stagger)
            for i in range(args.requests)]
    m = engine.serve(reqs, batch_size=args.batch)
    print(f"served {len(reqs)} requests on {args.batch} slots "
          f"({args.runtime}{'+offload' if args.offload else ''}, "
          f"{args.admission} admission, "
          f"{engine.attn_impl} attention): "
          f"prefill {m.prefill_s:.2f}s, "
          f"decode {m.tokens_out} tokens @ {m.decode_tps:.1f} tok/s, "
          f"slot occupancy {m.slot_occupancy:.2f}, "
          f"itl p50/p99 {m.itl_p50_s * 1e3:.1f}/{m.itl_p99_s * 1e3:.1f} ms")
    if args.offload:
        print(f"  wave buffer: hit {m.cache_hit_ratio:.3f} "
              f"(effective {m.effective_cache_hit_ratio:.3f}, "
              f"{m.cache_pending_hits} pending hits), "
              f"link {m.bytes_over_link / 2**20:.1f} MiB, "
              f"cache {m.bytes_from_cache / 2**20:.1f} MiB")
        if args.fault_profile or m.cache_faults or m.degraded_steps:
            print(f"  retrofault: {m.cache_faults} faults, "
                  f"{m.cache_retries} retries, "
                  f"{m.cache_corrupt_fetches} corrupt, "
                  f"{m.cache_failed_fetches} failed fetches; "
                  f"{m.degraded_steps}/{m.steps} degraded steps "
                  f"({m.dropped_cluster_steps} cluster-steps dropped)")
    for i, r in enumerate(reqs):
        status = "" if r.status == "ok" else f" [{r.status}]"
        print(f"  req {i}: prompt {len(r.prompt)}, out {len(r.out_tokens)}, "
              f"ttft {r.ttft_s:.2f}s, decode {r.decode_tps:.1f} tok/s"
              f"{status}")
    print("sample output tokens:", reqs[0].out_tokens[:10])


if __name__ == "__main__":
    main()
