"""Serving launcher: batched-request demo with the wave-index runtime.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma2_2b --reduced \
        --requests 4 --batch 2 --prompt-len 640 --new-tokens 16
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs.registry import get_config, reduced_config
from repro.models import model as M
from repro.serving.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--runtime", default="retro", choices=["retro", "full"])
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=640)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    params = M.init_params(cfg, jax.random.PRNGKey(args.seed))
    engine = ServeEngine(cfg, params, runtime=args.runtime, gen_headroom=512)
    rng = np.random.default_rng(args.seed)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab, args.prompt_len)
                    .astype(np.int32), max_new_tokens=args.new_tokens)
            for _ in range(args.requests)]
    metrics = engine.serve(reqs, batch_size=args.batch)
    for i, m in enumerate(metrics):
        print(f"wave {i}: prefill {m.prefill_s:.2f}s, "
              f"decode {m.tokens_out} tokens @ {m.decode_tps:.1f} tok/s")
    print("sample output tokens:", reqs[0].out_tokens[:10])


if __name__ == "__main__":
    main()
