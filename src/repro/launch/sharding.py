"""Sharding rules: map every parameter / serve-state / batch leaf to a
PartitionSpec over the ('pod', 'data', 'model') production mesh.

Conventions (Megatron-style tensor parallel + data parallel):
  * batch dims           -> ('pod','data') when divisible, else replicated
  * qkv/up projections   -> column-parallel (output dim on 'model')
  * out/down projections -> row-parallel (input dim on 'model')
  * MoE experts          -> expert axis on 'model' when E % model == 0,
                            else fall back to d_ff sharding (mixtral E=8)
  * embeddings / lm head -> vocab on 'model'
  * wave-index stores    -> kv-head axis on 'model' when divisible, else the
                            CLUSTER axis on 'model' (the baseline whose gather
                            collectives the §Perf loop attacks)
  * optimizer moments    -> same spec as their parameter (ZeRO-free TP)
"""
from __future__ import annotations

from typing import Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def batch_axes(mesh: Mesh, B: int):
    """Largest prefix of ('pod','data') that divides B."""
    names = mesh.axis_names
    if "pod" in names:
        pod, data = mesh.shape["pod"], mesh.shape["data"]
        if B % (pod * data) == 0:
            return ("pod", "data")
        if B % data == 0:
            return ("data",)
        return None
    data = mesh.shape["data"]
    return ("data",) if B % data == 0 else None


def _model_n(mesh: Mesh) -> int:
    return mesh.shape["model"]


def _path_names(path) -> Tuple[str, ...]:
    names = []
    for p in path:
        if hasattr(p, "key"):
            names.append(str(p.key))
        elif hasattr(p, "name"):
            names.append(str(p.name))
    return tuple(names)


def _rep(leaf):
    return P()


# ---------------------------------------------------------------------------
# parameters
# ---------------------------------------------------------------------------

def param_pspecs(cfg: ModelConfig, abstract_params, mesh: Mesh):
    mn = _model_n(mesh)

    def rule(path, leaf):
        names = _path_names(path)
        name = names[-1] if names else ""
        nd = len(leaf.shape)
        in_moe = "moe" in names
        in_attn = "attn" in names or "xattn" in names

        if name in ("embed",):
            return P("model", None) if leaf.shape[0] % mn == 0 else P()
        if name == "lm_head":
            return P(None, "model") if leaf.shape[1] % mn == 0 else P()
        if in_moe:
            E = cfg.moe.num_experts
            if name in ("w_gate", "w_up"):
                if E % mn == 0:
                    return P(None, "model", None, None)
                return P(None, None, None, "model")
            if name == "w_down":
                if E % mn == 0:
                    return P(None, "model", None, None)
                return P(None, None, "model", None)
            return P()                                     # router
        if in_attn:
            if name in ("wq", "wk", "wv"):
                spec = [None] * nd
                if leaf.shape[-1] % mn == 0:
                    spec[-1] = "model"
                return P(*spec)
            if name == "wo":
                spec = [None] * nd
                if leaf.shape[-2] % mn == 0:
                    spec[-2] = "model"
                return P(*spec)
        if name in ("w_gate", "w_up", "wr", "wk", "wv", "wg", "ck",
                    "in_proj", "cr"):
            spec = [None] * nd
            if leaf.shape[-1] % mn == 0:
                spec[-1] = "model"
            return P(*spec)
        if name in ("w_down", "wo", "cv", "out_proj"):
            spec = [None] * nd
            if leaf.shape[-2] % mn == 0:
                spec[-2] = "model"
            return P(*spec)
        return P()                                         # norms, scalars, ...

    return jax.tree_util.tree_map_with_path(rule, abstract_params)


# ---------------------------------------------------------------------------
# serve state
# ---------------------------------------------------------------------------

def wave_layout(cfg: ModelConfig, mesh: Mesh) -> str:
    """'head' when kv heads divide the model axis, else 'cluster'."""
    return "head" if cfg.attn and cfg.attn.n_kv_heads % _model_n(mesh) == 0 \
        else "cluster"


def serve_state_pspecs(cfg: ModelConfig, abstract_state, mesh: Mesh, B: int):
    """Shard the stacked per-layer KV/index state.

    Leading leaf dim is the layer (or site) stack; then (B, H, M, ...) for the
    wave index, (B, H, S, hd) for dense caches, (B, H, hd, hd|N) for
    recurrent states.
    """
    mn = _model_n(mesh)
    ba = batch_axes(mesh, B)
    layout = wave_layout(cfg, mesh)

    def rule(path, leaf):
        names = _path_names(path)
        name = names[-1] if names else ""
        nd = len(leaf.shape)
        if nd <= 1:                                        # scalars per layer
            return P()
        spec = [None] * nd
        # (L, B, ...) — batch on dim 1 where present
        if nd >= 2 and leaf.shape[1] == B and ba is not None:
            spec[1] = ba
        if name in ("k_store", "v_store", "pos_store", "centroid", "vsum",
                    "size", "stored", "max_pos"):
            if layout == "head" and leaf.shape[2] % mn == 0:
                spec[2] = "model"
            elif nd >= 4 and leaf.shape[3] % mn == 0:      # cluster axis M
                spec[3] = "model"
        elif name in ("k", "v") and nd == 5:               # DenseCache (L,B,H,S,hd)
            if leaf.shape[2] % mn == 0:
                spec[2] = "model"
            elif leaf.shape[3] % mn == 0:                  # sequence axis
                spec[3] = "model"
        elif name in ("ssm", "wkv") and nd == 5:           # (L,B,H,p,n)
            if leaf.shape[2] % mn == 0:
                spec[2] = "model"
        elif name in ("cross_k", "cross_v") and nd == 5:   # (L,B,F,H,hd)
            pass
        return P(*spec)

    return jax.tree_util.tree_map_with_path(rule, abstract_state)


# ---------------------------------------------------------------------------
# batches / train state
# ---------------------------------------------------------------------------

def batch_pspecs(cfg: ModelConfig, abstract_batch, mesh: Mesh):
    def rule(path, leaf):
        B = leaf.shape[0]
        ba = batch_axes(mesh, B)
        spec = [None] * len(leaf.shape)
        if ba is not None:
            spec[0] = ba
        return P(*spec)

    return jax.tree_util.tree_map_with_path(rule, abstract_batch)


def train_state_pspecs(cfg: ModelConfig, abstract_ts, mesh: Mesh):
    """TrainState(params, opt=AdamWState(step, mu, nu)) — moments follow
    their parameter's spec."""
    pp = param_pspecs(cfg, abstract_ts.params, mesh)
    from repro.training.optimizer import AdamWState
    from repro.training.train_loop import TrainState
    return TrainState(
        params=pp,
        opt=AdamWState(step=P(), mu=pp, nu=pp))


def to_named(tree_pspecs, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_pspecs,
                        is_leaf=lambda x: isinstance(x, P))
