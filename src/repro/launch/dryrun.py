import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

# Multi-pod dry-run: lower + compile every (arch x input-shape x mesh)
# combination on placeholder devices, record memory/cost analysis + roofline.
#
#   PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2_2b --shape decode_32k
#   PYTHONPATH=src python -m repro.launch.dryrun --all --mesh pod1 --out EXP.jsonl
#
# NOTE: the XLA_FLAGS lines above MUST precede any jax import (device count is
# locked at first init). Only this entrypoint sets it — tests/benches see 1 CPU.
import argparse
import json
import time
import traceback
from typing import Optional

import jax

from repro.configs.base import INPUT_SHAPES, InputShape, ModelConfig
from repro.configs.registry import ARCH_IDS, get_config, input_specs
from repro.launch import roofline as R
from repro.launch import sharding as S
from repro.launch.mesh import make_production_mesh
from repro.models import model as M
from repro.serving.steps import make_step
from repro.training.train_loop import TrainState


def _drop_lead(spec):
    from jax.sharding import PartitionSpec as P
    return P(*tuple(spec)[1:])


def skip_reason(cfg: ModelConfig, shape: InputShape) -> Optional[str]:
    return None  # every assigned arch has a decode path (see DESIGN)


def lower_one(arch: str, shape_name: str, *, multi_pod: bool = False,
              runtime: str = "retro", gen_headroom: int = 1024,
              verbose: bool = True, moe_groups: int = 0,
              serial_segments: bool = False, unroll_layers: bool = False,
              distributed: bool = False, per_layer_state: bool = False,
              cluster_cap: int = 0):
    cfg = get_config(arch)
    if moe_groups and cfg.moe is not None:
        cfg = cfg.replace(moe_dispatch_groups=moe_groups)
    import dataclasses
    if serial_segments:
        cfg = cfg.replace(retro=dataclasses.replace(
            cfg.retro, serial_prefill_segments=True))
    if cluster_cap:
        cfg = cfg.replace(retro=dataclasses.replace(
            cfg.retro, cluster_cap=cluster_cap))
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    chips = 512 if multi_pod else 256
    t0 = time.time()

    step = make_step(cfg, shape, runtime=runtime, gen_headroom=gen_headroom)
    batch_abs = input_specs(cfg, shape)

    with mesh:
        if shape.kind == "train":
            from repro.training.optimizer import init_adamw
            params_abs = M.param_specs(cfg)
            opt_abs = jax.eval_shape(init_adamw, params_abs)
            ts_abs = TrainState(params=params_abs, opt=opt_abs)
            ts_spec = S.to_named(S.train_state_pspecs(cfg, ts_abs, mesh), mesh)
            b_spec = S.to_named(S.batch_pspecs(cfg, batch_abs, mesh), mesh)
            jitted = jax.jit(step, in_shardings=(ts_spec, b_spec),
                             donate_argnums=(0,))
            lowered = jitted.lower(ts_abs, batch_abs)
        elif shape.kind == "prefill":
            params_abs = M.param_specs(cfg)
            p_spec = S.to_named(S.param_pspecs(cfg, params_abs, mesh), mesh)
            b_spec = S.to_named(S.batch_pspecs(cfg, batch_abs, mesh), mesh)
            jitted = jax.jit(step, in_shardings=(p_spec, b_spec))
            lowered = jitted.lower(params_abs, batch_abs)
        else:  # decode
            params_abs = M.param_specs(cfg)
            state_rt = "retro" if runtime == "retro_split" else runtime
            state_abs = M.serve_state_specs(cfg, shape.global_batch,
                                            shape.seq_len, runtime=state_rt,
                                            gen_headroom=gen_headroom)
            p_spec = S.to_named(S.param_pspecs(cfg, params_abs, mesh), mesh)
            s_spec = S.to_named(
                S.serve_state_pspecs(cfg, state_abs, mesh,
                                     shape.global_batch), mesh)
            t_spec = S.to_named(S.batch_pspecs(cfg, batch_abs, mesh), mesh)
            if runtime == "retro_split":
                from repro.models.transformer import split_state
                from repro.serving.steps import make_serve_step_split
                step = make_serve_step_split(
                    cfg, shape.seq_len, gen_headroom=gen_headroom,
                    unroll=unroll_layers or distributed,
                    mesh=mesh if distributed else None)
                cold_abs, hot_abs = split_state(state_abs.kv)
                cold_sp, hot_sp = split_state(s_spec.kv)
                if per_layer_state:
                    L = cfg.n_layers
                    cold_abs = [jax.tree.map(
                        lambda a: jax.ShapeDtypeStruct(a.shape[1:], a.dtype),
                        cold_abs) for _ in range(L)]
                    cold_sp = [jax.tree.map(
                        lambda ns: type(ns)(ns.mesh, _drop_lead(ns.spec)),
                        cold_sp) for _ in range(L)]
                jitted = jax.jit(step, in_shardings=(p_spec, cold_sp, hot_sp,
                                                     t_spec["token"]),
                                 donate_argnums=(2,))
                lowered = jitted.lower(params_abs, cold_abs, hot_abs,
                                       batch_abs["token"])
            else:
                jitted = jax.jit(step, in_shardings=(p_spec, s_spec,
                                                     t_spec["token"]),
                                 donate_argnums=(1,))
                lowered = jitted.lower(params_abs, state_abs,
                                       batch_abs["token"])

        lower_s = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        compile_s = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    coll = R.collective_bytes(compiled.as_text())
    peak = float(getattr(mem, "temp_size_in_bytes", 0)
                 + getattr(mem, "argument_size_in_bytes", 0)
                 + getattr(mem, "output_size_in_bytes", 0)
                 - getattr(mem, "alias_size_in_bytes", 0))
    rf = R.derive(cfg, shape, mesh_name, chips, cost, coll, peak_mem=peak,
                  note=f"runtime={runtime}"
                  + (f";moe_groups={moe_groups}" if moe_groups else "")
                  + (";serial_segments" if serial_segments else "")
                  + (";unroll" if unroll_layers else "")
                  + (";distributed" if distributed else "")
                  + (";per_layer_state" if per_layer_state else "")
                  + (f";cap={cluster_cap}" if cluster_cap else ""))
    rec = rf.as_dict()
    rec.update({
        "lower_s": round(lower_s, 1), "compile_s": round(compile_s, 1),
        "arg_bytes": float(getattr(mem, "argument_size_in_bytes", 0)),
        "temp_bytes": float(getattr(mem, "temp_size_in_bytes", 0)),
        "output_bytes": float(getattr(mem, "output_size_in_bytes", 0)),
        "coll_breakdown": {k: v for k, v in coll.items() if v},
        "runtime": runtime,
    })
    if verbose:
        print(f"[dryrun] {arch} x {shape_name} x {mesh_name} ({runtime}): "
              f"OK compile={compile_s:.1f}s "
              f"flops/chip={rec['flops_per_chip']:.3e} "
              f"bytes/chip={rec['bytes_per_chip']:.3e} "
              f"coll/chip={rec['coll_bytes_per_chip']:.3e} "
              f"dominant={rec['dominant']} peak_mem={peak/2**30:.2f}GiB")
        print(f"  memory_analysis: args={rec['arg_bytes']/2**30:.2f}GiB "
              f"temps={rec['temp_bytes']/2**30:.2f}GiB "
              f"out={rec['output_bytes']/2**30:.2f}GiB (per device)")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None,
                    choices=list(INPUT_SHAPES) + [None])
    ap.add_argument("--mesh", default="pod1", choices=["pod1", "pod2", "both"])
    ap.add_argument("--runtime", default="retro", choices=["retro", "full", "retro_split"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None, help="append results to jsonl")
    ap.add_argument("--moe-groups", type=int, default=0,
                    help="grouped MoE dispatch (Perf iteration; 0 = global)")
    ap.add_argument("--serial-segments", action="store_true",
                    help="lax.map prefill clustering (Perf iteration)")
    ap.add_argument("--unroll-layers", action="store_true",
                    help="unroll the decode layer scan (Perf iteration)")
    ap.add_argument("--distributed", action="store_true",
                    help="shard_map distributed retrieval (beyond-paper)")
    ap.add_argument("--per-layer-state", action="store_true",
                    help="per-layer cold-state args (Perf iteration)")
    ap.add_argument("--cluster-cap", type=int, default=0,
                    help="override retro cluster capacity (Perf iteration)")
    args = ap.parse_args()

    archs = list(ARCH_IDS) if (args.all or args.arch is None) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or args.shape is None) \
        else [args.shape]
    meshes = {"pod1": [False], "pod2": [True], "both": [False, True]}[args.mesh]

    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                try:
                    rec = lower_one(arch, shape, multi_pod=mp,
                                    runtime=args.runtime,
                                    moe_groups=args.moe_groups,
                                    serial_segments=args.serial_segments,
                                    unroll_layers=args.unroll_layers,
                                    distributed=args.distributed,
                                    per_layer_state=args.per_layer_state,
                                    cluster_cap=args.cluster_cap)
                    if args.out:
                        with open(args.out, "a") as f:
                            f.write(json.dumps(rec) + "\n")
                except Exception as e:
                    traceback.print_exc()
                    failures.append((arch, shape, mp, str(e)[:200]))
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print(" ", f)
        raise SystemExit(1)
    print("\nALL DRY-RUNS PASSED")


if __name__ == "__main__":
    main()
