"""Production meshes for the dry-run.

Functions (not module constants) so importing never touches jax device state.
Target: TPU v5e — 16x16 = 256 chips/pod, 2 pods = 512 chips.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for CPU multi-device tests (XLA_FLAGS host device count)."""
    return jax.make_mesh(shape, axes)


# TPU v5e hardware constants (per chip) for the roofline analysis.
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9                    # B/s
ICI_BW_PER_LINK = 50e9            # B/s (~per link)
HBM_BYTES = 16 * 1024 ** 3        # 16 GiB
