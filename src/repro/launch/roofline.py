"""Roofline-term derivation from compiled dry-run artifacts.

    compute term    = HLO_FLOPs_per_chip / peak_FLOP/s
    memory term     = HLO_bytes_per_chip / HBM_bw
    collective term = collective_bytes_per_chip / link_bw

``compiled.cost_analysis()`` reports the *partitioned per-device* module, so
per-chip terms come out directly (equivalent to the global/(chips·rate) form).
Collective bytes are not in cost_analysis — we parse the optimized HLO and sum
operand sizes of all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute ops.
"""
from __future__ import annotations

import re
from dataclasses import asdict, dataclass
from typing import Dict

from repro.configs.base import InputShape, ModelConfig
from repro.launch.mesh import HBM_BW, ICI_BW_PER_LINK, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  f32[8,128]{1,0}  or bf16[16]  (operand type tokens)
_SHAPE_RE = re.compile(r"\b(pred|s8|u8|s16|u16|bf16|f16|s32|u32|f32|s64|u64|"
                       r"f64|c64|c128)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum operand bytes per collective kind from optimized HLO text."""
    out = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        # match instruction lines: %x = TYPE collective-op(OPERANDS...)
        m = re.match(r"%?[\w.\-]+\s*=\s*[^=]*?\b([a-z\-]+)\(", s)
        if not m:
            continue
        op = m.group(1)
        kind = next((c for c in _COLLECTIVES if op.startswith(c)), None)
        if kind is None:
            continue
        # operand types are inside the call parens; result type precedes op.
        inside = s[s.index("(") + 1:]
        ops_bytes = sum(_shape_bytes(d, dims)
                        for d, dims in _SHAPE_RE.findall(inside))
        if ops_bytes == 0:  # fall back to result type (start-of-line)
            head = s[: s.index(op)]
            ops_bytes = sum(_shape_bytes(d, dims)
                            for d, dims in _SHAPE_RE.findall(head))
        out[kind] += ops_bytes
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_chip: float
    bytes_per_chip: float
    coll_bytes_per_chip: float
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops_global: float
    useful_ratio: float            # MODEL_FLOPS / (HLO_FLOPs * chips)
    peak_mem_bytes: float = 0.0
    note: str = ""

    def as_dict(self):
        return asdict(self)


def model_flops(cfg: ModelConfig, shape: InputShape) -> float:
    """MODEL_FLOPS convention: 6·N·D train, 2·N·D forward (N = active params)."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        toks = shape.seq_len * shape.global_batch
        return 6.0 * n_active * toks
    if shape.kind == "prefill":
        toks = shape.seq_len * shape.global_batch
        return 2.0 * n_active * toks
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch


def derive(cfg: ModelConfig, shape: InputShape, mesh_name: str, chips: int,
           cost: Dict, coll: Dict[str, int], peak_mem: float = 0.0,
           note: str = "") -> Roofline:
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    cb = float(coll.get("total", 0))
    cs = flops / PEAK_FLOPS_BF16
    ms = byts / HBM_BW
    ls = cb / ICI_BW_PER_LINK
    dom = max((("compute", cs), ("memory", ms), ("collective", ls)),
              key=lambda kv: kv[1])[0]
    mf = model_flops(cfg, shape)
    ratio = mf / max(flops * chips, 1.0)
    return Roofline(arch=cfg.arch_id, shape=shape.name, mesh=mesh_name,
                    chips=chips, flops_per_chip=flops, bytes_per_chip=byts,
                    coll_bytes_per_chip=cb, compute_s=cs, memory_s=ms,
                    collective_s=ls, dominant=dom, model_flops_global=mf,
                    useful_ratio=ratio, peak_mem_bytes=peak_mem, note=note)
