"""retrolint CLI — the repo's static + trace-time hot-path contract gate.

    python -m repro.launch.lint                  # full gate (CI entrypoint)
    python -m repro.launch.lint --no-trace       # static passes only (fast)
    python -m repro.launch.lint --explain RL201  # what a rule means / how to fix
    python -m repro.launch.lint --selftest       # every rule vs its fixtures
    python -m repro.launch.lint --write-baseline # suppress current findings
    python -m repro.launch.lint --json           # machine-readable findings
    python -m repro.launch.lint --json-out f.json  # also write JSON to a file
    python -m repro.launch.lint --github         # ::error workflow commands

Exit status: 0 when no unsuppressed error-severity finding remains (advice
never gates), 1 otherwise, 2 on usage errors. Suppression layers (narrowest
wins): `# retrolint: sync(<reason>)` / `# retrolint: ignore(RLxxx: <reason>)`
pragmas on the flagged line, then the checked-in ``lint_baseline.txt``.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Dict, List

from repro.analysis import ast_rules, pallas_check
from repro.analysis.findings import (RULES, Finding, apply_baseline,
                                     explain_rule, load_baseline,
                                     write_baseline)


def _repo_root(start: str) -> str:
    d = os.path.abspath(start)
    while d != os.path.dirname(d):
        if os.path.isdir(os.path.join(d, "src", "repro")):
            return d
        d = os.path.dirname(d)
    return os.path.abspath(start)


def _parse_geometry(spec: str) -> Dict[str, int]:
    geom: Dict[str, int] = {}
    for part in filter(None, (p.strip() for p in spec.split(","))):
        name, _, val = part.partition("=")
        try:
            geom[name.strip()] = int(val)
        except ValueError:
            raise SystemExit(f"bad --geometry entry {part!r} "
                             f"(want name=int,name=int,...)") from None
    return geom


def _finding_json(f: Finding) -> Dict:
    return {"rule": f.rule, "path": f.path, "line": f.line,
            "qualname": f.qualname, "message": f.message,
            "severity": f.severity, "fingerprint": f.fingerprint}


def _github_annotation(f: Finding) -> str:
    """One GitHub Actions workflow command per finding — surfaced inline on
    the PR diff by the runner. Newlines/percent must be URL-escaped per the
    workflow-command spec."""
    level = "error" if f.severity == "error" else "notice"
    msg = (f"({f.qualname}) {f.message}"
           .replace("%", "%25").replace("\r", "%0D").replace("\n", "%0A"))
    title = f"retrolint {f.rule}"
    return (f"::{level} file={f.path},line={max(f.line, 1)},"
            f"title={title}::{msg}")


def main(argv: List[str] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.launch.lint",
        description="static + trace-time hot-path contract checks")
    ap.add_argument("--root", default=".",
                    help="repo root (default: auto-detect from cwd)")
    ap.add_argument("--explain", metavar="RULE",
                    help="print a rule's rationale and fix guidance")
    ap.add_argument("--selftest", action="store_true",
                    help="run every rule against its known-good/bad fixtures")
    ap.add_argument("--no-trace", action="store_true",
                    help="skip the jaxpr/compile contract pass (no serve "
                         "runs; AST + Pallas only)")
    ap.add_argument("--baseline", default=None,
                    help="suppression baseline file "
                         "(default: <root>/lint_baseline.txt)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="rewrite the baseline to suppress current findings")
    ap.add_argument("--geometry", default="",
                    help="VMEM-estimate geometry overrides, name=int,... "
                         f"(defaults: {pallas_check.GEOMETRY_DEFAULTS})")
    ap.add_argument("--vmem-budget", type=int,
                    default=pallas_check.DEFAULT_VMEM_BUDGET,
                    help="VMEM budget in bytes for RL203")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="emit findings as a JSON object on stdout instead "
                         "of the human listing")
    ap.add_argument("--json-out", metavar="PATH", default=None,
                    help="additionally write the --json document to PATH "
                         "(CI uploads it as the RL406 cast-site inventory "
                         "artifact without a second gate run)")
    ap.add_argument("--github", action="store_true",
                    help="additionally emit GitHub Actions ::error/::notice "
                         "workflow commands (inline PR annotations)")
    ap.add_argument("-q", "--quiet", action="store_true",
                    help="only print findings, no progress")
    args = ap.parse_args(argv)

    if args.explain:
        text = explain_rule(args.explain.upper())
        if text is None:
            print(f"unknown rule {args.explain!r}; known: "
                  f"{', '.join(sorted(RULES))}", file=sys.stderr)
            return 2
        print(text)
        return 0

    log = (lambda *_: None) if args.quiet else \
        (lambda *m: print(*m, file=sys.stderr))

    if args.selftest:
        from repro.analysis.selftest import run_selftests
        log("retrolint: running rule self-tests")
        fails = run_selftests()
        if args.as_json:
            print(json.dumps({"selftest_failures": fails,
                              "ok": not fails}, indent=2))
            return 1 if fails else 0
        for f in fails:
            print(f"SELFTEST FAIL: {f}")
        print(f"retrolint selftest: "
              f"{'FAILED' if fails else 'ok'} ({len(fails)} failures)")
        return 1 if fails else 0

    root = _repo_root(args.root)
    baseline_path = args.baseline or os.path.join(root, "lint_baseline.txt")
    findings: List[Finding] = []

    log(f"retrolint: AST pass over {root}/src")
    findings += ast_rules.lint_tree(root)
    log("retrolint: Pallas kernel pass")
    findings += pallas_check.check_tree(
        root, geometry=_parse_geometry(args.geometry),
        vmem_budget=args.vmem_budget)
    if not args.no_trace:
        from repro.analysis.jaxpr_check import run_contract_checks
        from repro.analysis.numerics_check import run_numerics_checks
        findings += run_contract_checks(verbose=log)
        log("retrolint: retronum precision-flow pass (RL401-RL406)")
        findings += run_numerics_checks(verbose=log)

    if args.write_baseline:
        write_baseline(baseline_path, findings)
        print(f"baseline written: {baseline_path} "
              f"({sum(f.severity == 'error' for f in findings)} entries)")
        return 0

    visible = apply_baseline(findings, load_baseline(baseline_path))
    errors = [f for f in visible if f.severity == "error"]
    advice = [f for f in visible if f.severity != "error"]
    ordered = sorted(visible, key=lambda f: (f.path, f.line, f.rule))
    suppressed = len(findings) - len(visible)
    doc = {"findings": [_finding_json(f) for f in ordered],
           "errors": len(errors), "advice": len(advice),
           "baselined": suppressed, "ok": not errors}
    if args.as_json:
        print(json.dumps(doc, indent=2))
    else:
        for f in ordered:
            print(f.render())
    if args.json_out:
        with open(args.json_out, "w") as fh:
            json.dump(doc, fh, indent=2)
            fh.write("\n")
        log(f"retrolint: JSON findings written to {args.json_out}")
    if args.github:
        for f in ordered:
            print(_github_annotation(f))
    log(f"retrolint: {len(errors)} error(s), {len(advice)} advice, "
        f"{suppressed} baselined")
    if errors:
        log("retrolint: FAILED — `--explain <rule>` explains a finding; "
            "a pragma or the baseline suppresses a sanctioned one")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
