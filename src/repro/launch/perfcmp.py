import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

# Perf-comparison harness (§Perf): lowers ONE decode-attention layer at full
# production geometry under three runtimes and derives roofline terms:
#
#   full      — dense-KV full attention (the paper's baseline)
#   baseline  — paper-faithful wave attention under pjit: cluster stores
#               sharded on 'model', GLOBAL top-r, XLA inserts the gather
#               collectives (KV-bytes payload)
#   dist      — beyond-paper distributed wave attention: shard_map local
#               top-r/n + one LSE psum ((num,den,m) payload)
#
#   PYTHONPATH=src python -m repro.launch.perfcmp --arch gemma2_9b \
#       --shape long_500k --mode all --out perf.jsonl
import argparse
import json
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import INPUT_SHAPES
from repro.configs.registry import get_config
from repro.core import attention as wa
from repro.core.distributed import distributed_wave_attention
from repro.core.wave_index import init_wave_state
from repro.core.zones import plan_zones
from repro.launch import roofline as R
from repro.launch import sharding as S
from repro.launch.mesh import make_production_mesh


def _state_shardings(cfg, mesh, B, M, layout: str):
    """NamedShardings for a single-layer WaveState (B, H, M, ...)."""
    ba = S.batch_axes(mesh, B)

    def spec(name, nd, mdim):
        s = [None] * nd
        if ba is not None:
            s[0] = ba
        if layout == "cluster" and mdim is not None:
            s[mdim] = "model"
        return NamedSharding(mesh, P(*s))

    from repro.core.wave_index import WaveState
    fields = {
        "k_store": (5, 2), "v_store": (5, 2), "pos_store": (4, 2),
        "centroid": (4, 2), "vsum": (4, 2), "size": (3, 2), "stored": (3, 2),
        "max_pos": (3, 2), "n_clusters": (1, None), "sink_k": (4, None),
        "sink_v": (4, None), "local_k": (4, None), "local_v": (4, None),
        "local_len": (1, None), "length": (1, None),
    }
    return WaveState(**{f: (spec(f, nd, md) if nd else
                            NamedSharding(mesh, P()))
                        for f, (nd, md) in fields.items()})


def lower_mode(arch: str, shape_name: str, mode: str, multi_pod=False,
               verbose=True):
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    assert shape.kind == "decode"
    a, retro = cfg.attn, cfg.retro
    B, Sq = shape.global_batch, shape.seq_len
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = 512 if multi_pod else 256
    plan = plan_zones(Sq, retro, 1024)
    dt = jnp.dtype(cfg.dtype)
    q_abs = jax.ShapeDtypeStruct((B, a.n_heads, a.head_dim), dt)
    ba = S.batch_axes(mesh, B)
    q_shard = NamedSharding(mesh, P(ba, None, None))

    t0 = time.time()
    with mesh:
        if mode == "full":
            cache_abs = jax.eval_shape(
                lambda: wa.init_dense_cache(B, a.n_kv_heads, Sq + 1024,
                                            a.head_dim, dt))
            seq_ok = (Sq + 1024) % mesh.shape["model"] == 0
            c_spec = jax.tree.map(
                lambda l: NamedSharding(mesh, P(
                    ba, None, "model" if (l.ndim == 4 and seq_ok) else None))
                if l.ndim else NamedSharding(mesh, P()), cache_abs)

            def step(q, cache):
                return wa.full_attention_decode(q, cache, softcap=a.softcap)

            lowered = jax.jit(step, in_shardings=(q_shard, c_spec)).lower(
                q_abs, cache_abs)
        else:
            state_abs = jax.eval_shape(
                lambda: init_wave_state(B, a.n_kv_heads, a.head_dim,
                                        plan.m_max, retro, dt))
            layout = "cluster"
            s_spec = _state_shardings(cfg, mesh, B, plan.m_max, layout)
            if mode == "baseline":
                def step(q, state):
                    return wa.wave_attention_decode(
                        q, state, retro, plan, softcap=a.softcap).out
            else:  # dist
                def step(q, state):
                    return distributed_wave_attention(
                        q, state, retro, plan, mesh, softcap=a.softcap)
            lowered = jax.jit(step, in_shardings=(q_shard, s_spec)).lower(
                q_abs, state_abs)
        compiled = lowered.compile()
    compile_s = time.time() - t0
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    coll = R.collective_bytes(compiled.as_text())
    rf = R.derive(cfg, shape, "2x16x16" if multi_pod else "16x16", chips,
                  cost, coll, note=f"attnlayer-{mode}")
    rec = rf.as_dict()
    rec.update({"mode": mode, "compile_s": round(compile_s, 1),
                "coll_breakdown": {k: v for k, v in coll.items() if v}})
    if verbose:
        print(f"[perfcmp] {arch} x {shape_name} [{mode}]: "
              f"flops={rec['flops_per_chip']:.3e} "
              f"bytes={rec['bytes_per_chip']:.3e} "
              f"coll={rec['coll_bytes_per_chip']:.3e} "
              f"terms(s)=({rec['compute_s']:.2e},{rec['memory_s']:.2e},"
              f"{rec['collective_s']:.2e}) dom={rec['dominant']}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2_9b")
    ap.add_argument("--shape", default="long_500k",
                    choices=["decode_32k", "long_500k"])
    ap.add_argument("--mode", default="all",
                    choices=["full", "baseline", "dist", "all"])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    modes = ["full", "baseline", "dist"] if args.mode == "all" else [args.mode]
    for mode in modes:
        rec = lower_mode(args.arch, args.shape, mode,
                         multi_pod=args.multi_pod)
        if args.out:
            with open(args.out, "a") as f:
                f.write(json.dumps(rec) + "\n")


if __name__ == "__main__":
    main()
