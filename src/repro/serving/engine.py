"""Continuous-batching serving engine with chunked admission and a sync-free
decode loop.

The decode loop runs a fixed number of SLOTS (the decode batch). Each slot
holds at most one in-flight request; finished requests free their slot and
queued requests are admitted mid-stream. Per-request wave-index bookkeeping
(``length``/``local_len``/``n_clusters`` are (B,) arrays) lets ragged
requests sit at different positions in one batch; staging-buffer flushes are
per-row masked, so rows flush on their own schedule.

Admission (``admission="chunked"``, the default where the family supports
it): a request's prompt is consumed one fixed-size chunk per scheduler
iteration, interleaved between decode steps, so in-flight decodes never stall
longer than one chunk. One compiled chunk shape (the final chunk is
right-padded and masked) replaces the per-bucket prefill jit cache; the wave
index is built incrementally (``prefill_append_chunk``) and finalized
bit-identically to the monolithic build. ``admission="blocking"`` keeps the
monolithic per-slot prefill (bucketed/jit-cached) for comparison and for the
pass-through families (encdec/hybrid/ssm), which fall back automatically.

The decode loop issues NO host sync between consecutive decode dispatches:
tokens are sampled on device and fed device-to-device into the next step; the
ids of step t are read back (the loop's only sync) only after step t+1 has
been dispatched. Completion is therefore detected one step late — the extra
speculative token of a just-finished request is dropped on harvest, and its
slot's state is overwritten by the next admission graft. First tokens of all
requests admitted in the same iteration are sampled with ONE coalesced
device->host readback.

Host-offload mode (``offload=True``, paper Sec. 4.3): the cluster payload
stores live host-side behind per-(layer, slot, kv-head) ``WaveBuffer``s and
decode attention reads a per-layer device block cache through cache-slot
indirection — hits from the cache store, misses fetched over the link into a
per-step staging tail — with cache admissions deferred off the hot path.
Token-for-token identical to the direct-store path; the decode loop then
syncs retrieved ids once per layer (the paper's CPU control plane), trading
the sync-free loop for bounded device memory. See ``_OffloadPlane``.

Metrics are per-request (TTFT, decode tok/s) plus engine-level slot occupancy,
aggregate throughput, and inter-token latency (p50/p99 over gaps between
consecutive token deliveries of continuing requests — the decode-interference
signal chunked admission exists to shrink). Only real requests count: free
slots produce logits that are never sampled, so padding can't inflate
``tokens_out``. Offload serving adds the wave-buffer counters (hit ratio,
bytes over the link / from cache / from pending, pending hits) aggregated
over every per-row block cache.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from functools import partial
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.wave_buffer import (BufferStats, FatalTransportError,
                                    FaultProfile, FaultyTransport,
                                    LinkTransport, WaveBuffer)
from repro.core.wave_index import local_buffer_size
from repro.core.zones import plan_zones
from repro.models import model as M
from repro.models.model import ATTN_FAMILIES
from repro.models.transformer import HOT_FIELDS, LIVE_FIELDS


@dataclass
class Request:
    prompt: np.ndarray                  # (S,) int32
    max_new_tokens: int
    out_tokens: List[int] = field(default_factory=list)
    done: bool = False
    extra: Optional[Dict] = None        # per-request prefill extras (e.g. vlm)
    # ---- filled by the engine ----
    ttft_s: float = 0.0                 # enqueue -> first token
    decode_tps: float = 0.0             # this request's decode tokens/s
    # "ok" | "timeout" (max-decode-steps watchdog) | "error" (unrecoverable
    # transport fault) — structured per-request completion status; non-ok
    # requests still free their slot and the scheduler keeps serving
    status: str = "ok"


@dataclass
class ServeMetrics:
    """Aggregate serve metrics. Padding/free slots never contribute: only
    sampled tokens of real requests are counted."""
    prefill_s: float = 0.0
    decode_s: float = 0.0
    tokens_out: int = 0
    steps: int = 0                      # decode steps executed
    occupied_slot_steps: int = 0        # sum over steps of active slots
    n_slots: int = 0
    ttft_s: List[float] = field(default_factory=list)
    request_tps: List[float] = field(default_factory=list)
    # gaps between consecutive token deliveries of continuing requests —
    # includes any admission work scheduled in between (the interference term)
    step_s: List[float] = field(default_factory=list)
    # host-offload wave-buffer counters (Fig. 16 at serve level; zero unless
    # the engine runs with offload=True) — aggregated over every per-row
    # block cache, including caches retired when their slot was re-admitted
    cache: "BufferStats" = field(default_factory=BufferStats)
    # degraded decode (retrofault): steps whose attend ran with >= 1 cluster
    # masked out of the retrieval zone (fetch failed its deadline/retries,
    # mass covered by the estimation zone), and the cluster·step drop count
    degraded_steps: int = 0
    dropped_cluster_steps: int = 0

    @property
    def decode_tps(self) -> float:
        return self.tokens_out / max(self.decode_s, 1e-9)

    # -- delegated wave-buffer counters (single source of truth: BufferStats)
    @property
    def cache_lookups(self) -> int:
        return self.cache.lookups

    @property
    def cache_hits(self) -> int:
        return self.cache.hits

    @property
    def cache_pending_hits(self) -> int:
        return self.cache.pending_hits

    @property
    def bytes_over_link(self) -> int:
        return self.cache.bytes_over_link

    @property
    def bytes_from_cache(self) -> int:
        return self.cache.bytes_from_cache

    @property
    def bytes_from_pending(self) -> int:
        return self.cache.bytes_from_pending

    # -- fault/retry aggregates (retrofault; zero on a clean link)
    @property
    def cache_faults(self) -> int:
        return self.cache.faults

    @property
    def cache_retries(self) -> int:
        return self.cache.retries

    @property
    def cache_corrupt_fetches(self) -> int:
        return self.cache.corrupt_fetches

    @property
    def cache_failed_fetches(self) -> int:
        return self.cache.failed_fetches

    @property
    def cache_hit_ratio(self) -> float:
        return self.cache.hit_ratio

    @property
    def effective_cache_hit_ratio(self) -> float:
        """Includes pending hits (repeat misses served without a second link
        transfer) — the traffic-relevant hit rate."""
        return self.cache.effective_hit_ratio

    @property
    def slot_occupancy(self) -> float:
        return self.occupied_slot_steps / max(self.steps * self.n_slots, 1)

    @property
    def itl_p50_s(self) -> float:
        return float(np.percentile(self.step_s, 50)) if self.step_s else 0.0

    @property
    def itl_p99_s(self) -> float:
        return float(np.percentile(self.step_s, 99)) if self.step_s else 0.0

    @property
    def ttft_p50_s(self) -> float:
        return float(np.percentile(self.ttft_s, 50)) if self.ttft_s else 0.0

    @property
    def ttft_p99_s(self) -> float:
        return float(np.percentile(self.ttft_s, 99)) if self.ttft_s else 0.0


# back-compat alias (pre-continuous engines returned per-wave metrics)
WaveMetrics = ServeMetrics


# ---------------------------------------------------------------------------
# Stage contract, consumed by the retrolint jaxpr checker (repro.analysis).
#
# Every jitted serve stage is registered here by its function __name__ with
# the donations it MUST declare (and which must lower to true output aliases
# — rule RL102) and its compile budget over a serve run (rule RL103):
#   * "per_geometry":      compiles exactly once per engine geometry
#   * "per_prompt_len":    once per distinct admitted prompt length
#   * "per_prompt_bucket": once per distinct bucketed prompt length
#                          (blocking admission only)
# Adding a jitted stage to the engine without registering it here fails the
# lint gate, which is the point: the contract is the reviewable artifact.
#
# PR 7 (retrosched) extends every entry with its EFFECTS — the abstract
# buffers the stage reads / writes / donates / passes (donated-and-carried:
# the output aliases the input unchanged) — and the memory ``space`` it runs
# in. Buffer names come from ``analysis.schedule_model.BUFFER_SPACE``;
# ``[l]`` means the event's layer instance, ``[*]`` every layer. Host
# control-plane ops of the offload decode step (``space="host"``,
# ``budget="host"``: not jitted, so no compile budget or donation lowering
# applies) are registered in the same table so the whole schedule contract
# is one reviewable artifact; the happens-before checker (RL301-RL305)
# resolves recorded schedule events against these declarations. How to
# declare effects for a new stage: src/repro/analysis/README.md.
# ---------------------------------------------------------------------------
SERVE_STAGES: Dict[str, Dict[str, Any]] = {
    # engine-lifetime jits (built in __init__)
    "graft":           dict(donate=(0,), budget="per_geometry",
                            space="device",
                            effects=dict(reads=("serve_state", "slot_state"),
                                         writes=("serve_state",),
                                         donates=("serve_state",))),
    "argmax_ids":      dict(donate=(), budget="per_geometry", space="device",
                            effects=dict(reads=("logits",),
                                         writes=("tokens",))),
    "categorical_ids": dict(donate=(), budget="per_geometry", space="device",
                            effects=dict(reads=("logits",),
                                         writes=("tokens",))),
    "merge_tokens":    dict(donate=(), budget="per_geometry", space="device",
                            effects=dict(reads=("tokens",),
                                         writes=("tokens",))),
    # admission
    "prefill":         dict(donate=(), budget="per_prompt_bucket",
                            space="device",
                            effects=dict(reads=("prompt",),
                                         writes=("slot_state",))),
    "chunk":           dict(donate=(1,), budget="per_geometry",
                            space="device",
                            effects=dict(reads=("prompt", "chunk_state"),
                                         writes=("chunk_state",),
                                         donates=("chunk_state",))),
    "chunk_pe":        dict(donate=(1,), budget="per_geometry",
                            space="device",
                            effects=dict(reads=("prompt", "chunk_state"),
                                         writes=("chunk_state",),
                                         donates=("chunk_state",))),
    # fin's chunk state (arg 1) stays un-donated on purpose: finalize
    # TRANSFORMS the staged tail (clustering) rather than updating it in
    # place, so most leaves cannot alias an output and a donation would
    # silently degrade to copies (RL102 would rightly fail); copy_ok
    # records the exemption for the RL104 missed-donation advice
    "fin":             dict(donate=(0,), budget="per_prompt_len",
                            copy_ok=(1,), space="device",
                            effects=dict(reads=("serve_state",
                                                "chunk_state"),
                                         writes=("serve_state",
                                                 "slot_state"),
                                         donates=("serve_state",))),
    # direct-store decode
    "decode":          dict(donate=(1,), budget="per_geometry",
                            space="device",
                            effects=dict(reads=("tokens", "serve_state"),
                                         writes=("logits", "serve_state"),
                                         donates=("serve_state",))),
    "flush":           dict(donate=(0,), budget="per_geometry",
                            space="device",
                            effects=dict(reads=("serve_state",),
                                         writes=("serve_state",),
                                         donates=("serve_state",))),
    # host-offload decode plane (device stream)
    "embed_tokens":    dict(donate=(), budget="per_geometry", space="device",
                            effects=dict(reads=("tokens",),
                                         writes=("hidden",))),
    "rank_fn":         dict(donate=(2,), budget="per_geometry",
                            space="device",
                            effects=dict(reads=("hidden", "live[l]"),
                                         writes=("ctx[l]", "ids[l]",
                                                 "live[l]"),
                                         donates=("live[l]",))),
    "attend_fn":       dict(donate=(), budget="per_geometry", space="device",
                            effects=dict(reads=("hidden", "ctx[l]",
                                                "live[l]", "cache_body[l]",
                                                "cache_tail[l]", "slots[l]",
                                                "valid[l]"),
                                         writes=("hidden",))),
    "unembed_logits":  dict(donate=(), budget="per_geometry", space="device",
                            effects=dict(reads=("hidden",),
                                         writes=("logits",))),
    "cache_upd":       dict(donate=(0, 1, 2), budget="per_geometry",
                            space="device",
                            # the staging tail is overwritten wholesale (all
                            # r slots restaged every step), so it is not a
                            # data read; the body IS (scatter preserves
                            # un-admitted slots)
                            effects=dict(reads=("cache_body[l]",
                                                "adm_queue[l]", "miss[l]"),
                                         writes=("cache_body[l]",
                                                 "cache_tail[l]"),
                                         donates=("cache_body[l]",
                                                  "cache_tail[l]"))),
    # cache_stage donates the whole cache array but only WRITES the staging
    # tail — the body rides through as an aliased output (``passes``), which
    # is what keeps RL305 from treating the body as clobbered
    "cache_stage":     dict(donate=(0, 1, 2), budget="per_geometry",
                            space="device",
                            effects=dict(reads=("miss[l]",),
                                         writes=("cache_tail[l]",),
                                         donates=("cache_body[l]",
                                                  "cache_tail[l]"),
                                         passes=("cache_body[l]",))),
    "offload_flush":   dict(donate=(0,), budget="per_geometry",
                            space="device",
                            effects=dict(reads=("live[*]",),
                                         writes=("live[*]", "flush_blocks"),
                                         donates=("live[*]",))),
    # host control plane of the offload decode step (not jitted; traced as
    # schedule events via _OffloadPlane.trace)
    "readback_start":  dict(donate=(), budget="host", space="host",
                            effects=dict(reads=("ids[l]",))),
    "readback_ids":    dict(donate=(), budget="host", space="host",
                            effects=dict(reads=("ids[l]",),
                                         writes=("ids_host[l]",))),
    # translate additionally builds the per-cluster validity mask (valid[l],
    # link space): 0 marks a miss whose fetch failed its retry/deadline
    # budget this step — attend masks it out of the retrieval zone and the
    # estimation zone covers its mass (degraded decode, retrofault)
    "translate":       dict(donate=(), budget="host", space="host",
                            effects=dict(reads=("ids_host[l]", "cmt[l]",
                                                "host_store[l]",
                                                "pending[l]"),
                                         writes=("slots[l]", "miss[l]",
                                                 "valid[l]", "pending[l]",
                                                 "cmt[l]"))),
    "drain_admissions": dict(donate=(), budget="host", space="host",
                             effects=dict(reads=("pending[l]",
                                                 "host_store[l]"),
                                          writes=("cmt[l]", "pending[l]",
                                                  "adm_queue[l]"))),
    "readback_flush":  dict(donate=(), budget="host", space="host",
                            effects=dict(reads=("flush_blocks",))),
    "host_flush":      dict(donate=(), budget="host", space="host",
                            effects=dict(writes=("host_store[*]",))),
    "admit_slot":      dict(donate=(), budget="host", space="host",
                            effects=dict(writes=("host_store[*]", "cmt[*]",
                                                 "pending[*]",
                                                 "adm_queue[*]"))),
}

# retronum (PR 10): the per-stage NUMERICS contract, checked by the
# precision-flow pass (rules RL401-RL406, ``analysis/numerics_check.py``)
# over every recorded stage trace. Schema (``analysis/README.md``):
#   softmax — dtype floor for exp/log/LSE-chain transcendentals (RL401)
#   accum   — dtype floor for dot_general accumulation       (RL402)
#   narrow  — "output-only": the final astype(q.dtype) and same-dtype
#             storage writes are the ONLY sanctioned narrowings
#             (RL403/RL404); "free" opts a stage out
# Every device stage runs under the default f32 contract; a stage needing
# a different floor declares its own ``numerics=`` inline (setdefault
# below respects it). Host control-plane steps hold no traced math, so
# they carry no contract.
NUMERICS_F32: Dict[str, str] = dict(softmax="float32", accum="float32",
                                    narrow="output-only")
for _contract in SERVE_STAGES.values():
    if _contract["space"] == "device":
        _contract.setdefault("numerics", NUMERICS_F32)
del _contract


@dataclass
class _Admission:
    """One slot's in-progress chunked admission (or a just-finished blocking
    prefill awaiting its coalesced first-token sample)."""
    req: Request
    cstate: Any = None                  # PrefillChunkState (chunked mode)
    consumed: int = 0
    logits: Any = None                  # device logits of the last chunk


class _OffloadPlane:
    """Host control plane of one offload serve() call (paper Sec. 4.3).

    The cluster PAYLOAD stores live host-side, one ``WaveBuffer`` per
    (layer, slot, kv-head) row over PACKED per-cluster payload rows
    ``[K | V | positions]`` (f32 — exact for bf16/f32 stores and integer
    positions, so cache placement is bit-transparent). The device keeps, per
    layer, a block-cache store of ``C + r`` slots: slots [0, C) mirror each
    row's ``WaveBuffer.cache`` and the tail r slots are the per-step miss
    staging buffer. Each decode step runs per layer:

      rank (jit) -> ids readback -> translate ids through the mapping tables
      (hits -> cache slots, misses -> staging slots; misses fetched from the
      host store) -> cache update (jit: previous step's deferred admissions +
      this step's staged misses) -> attend (jit, slot-indirected paged
      kernel) -> ``apply_updates`` (host, OFF the hot path; admissions mirror
      into the device cache at the NEXT step's cache update).

    The loop is LAYER-PIPELINED (retrosched's RL304 report, PR 7): right
    after layer l's attend is dispatched, layer l+1's rank is dispatched and
    its id readback STARTED (``copy_to_host_async``); only then does layer
    l's deferred-admission drain run on the host. Layer l+1's blocking id
    sync therefore overlaps the drain and the device's cache-update + attend
    + rank work instead of idling behind them. Every dispatch / host op /
    sync calls ``trace`` (a no-op hooked by
    ``analysis.schedule_model.ScheduleRecorder``), and the recorded schedule
    is model-checked against the SERVE_STAGES effects declarations by
    RL301-RL305 in CI — the pipeline ships as a checked refactor, not a
    leap of faith.
    """

    def trace(self, op: str, layer: int, kind: str, step: int,
              **extras) -> None:
        """Schedule-event hook, one call per dispatch / host op / sync in
        program order. A no-op in production; ``ScheduleRecorder`` patches
        it at class level to record the happens-before event stream."""

    def __init__(self, engine: "ServeEngine", B: int, max_ctx: int):
        cfg = engine.cfg
        self.cfg = cfg
        self.params = engine.params
        self.plan = plan_zones(max_ctx, cfg.retro, engine.gen_headroom)
        self.L, self.B, self.H = cfg.n_layers, B, cfg.n_kv_heads
        self.hd, self.cap, self.M = cfg.head_dim, cfg.retro.cluster_cap, \
            self.plan.m_max
        self.r = max(self.plan.r, 1)        # staging tail (dead slot if r=0)
        self.C = engine._resolve_cache_clusters(self.M)
        self.policy = engine.cache_policy
        self.dtype = jnp.dtype(cfg.dtype)
        C, r, cap, hd = self.C, self.r, self.cap, self.hd
        self.cache_k = [jnp.zeros((B, self.H, C + r, cap, hd), self.dtype)
                        for _ in range(self.L)]
        self.cache_v = [jnp.zeros((B, self.H, C + r, cap, hd), self.dtype)
                        for _ in range(self.L)]
        self.cache_p = [jnp.full((B, self.H, C + r, cap), -1, jnp.int32)
                        for _ in range(self.L)]
        # per (layer, slot, head) host buffer; None until the slot is admitted
        self.bufs: List[List[Optional[List[WaveBuffer]]]] = [
            [None] * B for _ in range(self.L)]
        # per-layer queued device-cache mirror of deferred admissions;
        # None = nothing admitted (the mirror transfer + scatter is skipped)
        self.pending_adm: List[Optional[Tuple[np.ndarray, ...]]] = \
            [None] * self.L
        self.ncl = np.zeros(B, np.int64)    # host mirror of n_clusters
        self.retired = BufferStats()        # stats of replaced slot caches
        self._step = -1                     # schedule epoch for trace events
        # retrofault: ONE transport per plane, shared by every per-row wave
        # buffer — the control plane is single-threaded, so a seeded
        # FaultyTransport yields one reproducible fault schedule per serve
        self.transport = (FaultyTransport(engine.fault_profile)
                          if engine.fault_profile is not None
                          else LinkTransport())
        self.fetch_retries = engine.fetch_retries
        self.fetch_backoff_s = engine.fetch_backoff_s
        self.fetch_deadline_s = engine.fetch_deadline_s
        self.degraded_steps = 0             # steps with >= 1 masked cluster
        self.dropped_cluster_steps = 0      # cluster·step masked count
        self.failed_slots: Dict[int, str] = {}   # slot -> fatal fault message
        (self._embed, self._rank, self._attend, self._unembed,
         self._cache_upd, self._cache_stage, self._flush) = \
            engine._offload_fns(B, max_ctx, self.C, self.r)
        self._layers = [jax.tree.map(lambda a, i=i: a[i], engine.params["layers"])
                        for i in range(self.L)]
        self._windows = [engine.params["window"][i] for i in range(self.L)]

    # ------------------------------------------------------------- packing
    def _pack(self, k, v, p) -> np.ndarray:
        """(M', cap, hd) x2 + (M', cap) -> (M', D) packed f32 payload rows."""
        m = k.shape[0]
        return np.concatenate([
            np.asarray(k, np.float32).reshape(m, -1),
            np.asarray(v, np.float32).reshape(m, -1),
            np.asarray(p, np.float32)], axis=1)

    def _unpack(self, rows: np.ndarray):
        """(n, D) packed rows -> k/v (n, cap, hd) f32 + pos (n, cap) int32."""
        n, cap, hd = rows.shape[0], self.cap, self.hd
        k = rows[:, :cap * hd].reshape(n, cap, hd)
        v = rows[:, cap * hd:2 * cap * hd].reshape(n, cap, hd)
        p = rows[:, 2 * cap * hd:].astype(np.int32)
        return k, v, p

    # ----------------------------------------------------------- admission
    def admit_slot(self, i: int, st1) -> None:      # retrolint: hot
        """Offload a freshly admitted request's cluster stores: device->host
        transfer of slot ``i``'s payload blocks, fresh mapping tables (the
        previous occupant's cache entries die with it; its stats are retired
        into the engine aggregate)."""
        self._step += 1
        self.trace("admit_slot", -1, "host", self._step)
        # sanctioned syncs: the admission-time device->host store transfer IS
        # the offload (one per admitted request, amortized over its decode)
        k_all = np.asarray(  # retrolint: sync(admission store offload)
            st1.kv.k_store)[:, 0]                       # (L, H, M, cap, hd)
        v_all = np.asarray(  # retrolint: sync(admission store offload)
            st1.kv.v_store)[:, 0]
        p_all = np.asarray(  # retrolint: sync(admission store offload)
            st1.kv.pos_store)[:, 0]
        self.ncl[i] = int(
            np.asarray(  # retrolint: sync(admission cluster-count mirror)
                st1.kv.n_clusters)[0, 0])
        for l in range(self.L):
            old = self.bufs[l][i]
            if old is not None:
                for buf in old:
                    self.retired.merge(buf.stats)
            self.bufs[l][i] = [
                WaveBuffer(self._pack(k_all[l, h], v_all[l, h], p_all[l, h]),
                           cache_clusters=self.C, policy=self.policy,
                           transport=self.transport,
                           max_retries=self.fetch_retries,
                           backoff_s=self.fetch_backoff_s)
                for h in range(self.H)]
            # drop pending admissions aimed at the replaced slot's caches
            if self.pending_adm[l] is not None:
                slots, ak, av, ap = self.pending_adm[l]
                slots = slots.copy()
                slots[i] = self.C + self.r              # OOB => dropped write
                self.pending_adm[l] = (slots, ak, av, ap)

    # ------------------------------------------------------- control plane
    def _translate(self, l, ids, active):           # retrolint: hot
        """Cluster ids -> combined cache-slot ids; fetch miss payloads.

        Ids of not-yet-live clusters (>= the row's ``n_clusters`` mirror —
        ``top_k`` tie-breaks the NEG-masked dead scores to exactly the ids
        the next flush will allocate) NEVER touch the wave buffer: fetching
        them would admit an all-masked payload that would later be served as
        a STALE hit once the flush writes the real blocks at those ids. They
        map to their staging slot instead, whose default ``pos = -1`` payload
        reproduces the direct path's dead-block masking bit-for-bit.

        Also returns the per-cluster validity mask ``valid`` (B, H, r)
        int32 (retrofault): 0 marks a LIVE cluster whose miss fetch failed
        its retry/deadline budget this step — its staging slot holds the
        self-masking default payload and the attend covers its mass with the
        estimation zone. Dead ids stay valid=1 (their pos=-1 staging payload
        already reproduces the direct path bit-for-bit, and masking them
        would diverge from it). A :class:`FatalTransportError` marks the
        whole slot failed (``failed_slots``) — the serve loop finishes that
        request with ``status="error"`` after the step; remaining slots are
        untouched (no engine-wide quarantine).
        """
        B, H, r = ids.shape
        cap, hd = self.cap, self.hd
        idx_slots = np.zeros((B, H, r), np.int32)
        valid = np.ones((B, H, r), np.int32)
        miss_k = np.zeros((B, H, self.r, cap, hd), np.float32)
        miss_v = np.zeros((B, H, self.r, cap, hd), np.float32)
        miss_p = np.full((B, H, self.r, cap), -1, np.int32)
        if r == 0:      # steady-zone-only plan: attend pads its own dead slot
            return idx_slots, valid, miss_k, miss_v, miss_p
        stage = self.C + np.arange(r)
        for b in range(B):
            if not active[b] or self.bufs[l][b] is None \
                    or b in self.failed_slots:
                continue
            dead = ids[b] >= self.ncl[b]                    # (H, r)
            for h in range(H):
                buf = self.bufs[l][b][h]
                live_j = np.where(~dead[h])[0]
                idx_slots[b, h] = stage                     # default: staging
                if len(live_j) == 0:
                    continue
                try:
                    slot, hit, payload, ok = buf.translate(
                        ids[b, h, live_j], deadline_s=self.fetch_deadline_s)
                except FatalTransportError as e:
                    # kill only this slot; partial per-head state for the
                    # step is harmless (staged defaults self-mask) because
                    # the request is finished before its token is harvested
                    self.failed_slots[b] = str(e)
                    break
                idx_slots[b, h, live_j] = np.where(
                    hit, slot, stage[live_j]).astype(np.int32)
                valid[b, h, live_j[~ok]] = 0
                self.dropped_cluster_steps += int((~ok).sum())
                miss_j = live_j[~hit & ok]
                if len(miss_j):
                    mk, mv, mp = self._unpack(payload[~hit & ok])
                    miss_k[b, h, miss_j] = mk
                    miss_v[b, h, miss_j] = mv
                    miss_p[b, h, miss_j] = mp
        return idx_slots, valid, miss_k, miss_v, miss_p

    def _drain_admissions(self, l, active) -> bool:  # retrolint: hot
        """Apply deferred WaveBuffer admissions (off the attend hot path) and
        queue their device-cache mirror for the next step's cache update.
        A warm-cache step with zero admissions queues None — the next cache
        update then skips the mirror transfer + scatter entirely. Returns
        whether anything was queued (the RL302 mirror-edge trace bit)."""
        B, H, r = self.B, self.H, self.r
        queued = None
        for b in range(B):
            if not active[b] or self.bufs[l][b] is None:
                continue
            for h in range(H):
                n = 0
                for vict, _ids, payload in self.bufs[l][b][h].apply_updates():
                    if queued is None:
                        queued = (
                            np.full((B, H, r), self.C + r, np.int32),  # OOB
                            np.zeros((B, H, r, self.cap, self.hd),
                                     np.float32),
                            np.zeros((B, H, r, self.cap, self.hd),
                                     np.float32),
                            np.full((B, H, r, self.cap), -1, np.int32))
                    slots, ak, av, ap = queued
                    m = len(vict)
                    pk, pv, pp = self._unpack(payload)
                    slots[b, h, n:n + m] = vict
                    ak[b, h, n:n + m] = pk
                    av[b, h, n:n + m] = pv
                    ap[b, h, n:n + m] = pp
                    n += m
        self.pending_adm[l] = queued
        return queued is not None

    # ------------------------------------------------------------- decode
    def _launch_rank(self, l, kv, x, act_dev, t):   # retrolint: hot
        """Dispatch layer ``l``'s rank and START its retrieved-id readback
        (``copy_to_host_async`` — non-blocking; the transfer overlaps
        whatever the host and device do next). The matching blocking sync
        happens at this layer's loop iteration in ``decode_step``."""
        live = {f: getattr(kv, f)[l] for f in LIVE_FIELDS}
        self.trace("rank_fn", l, "dispatch", t)
        ctx, idx_r, live = self._rank(self._layers[l], self._windows[l],
                                      live, x, act_dev)
        self.trace("readback_start", l, "host", t)
        idx_r.copy_to_host_async()
        return ctx, idx_r, live

    def decode_step(self, state, tokens_dev, active):  # retrolint: hot
        """One decode step over the slot batch, layer-pipelined: layer l+1's
        rank is dispatched and its id readback started BEFORE layer l's
        deferred-admission drain runs, so the per-layer id sync overlaps the
        drain and the device's cache-update/attend/rank work (see the class
        docstring; retrosched certifies the order). Returns (device logits,
        new state)."""
        self._step += 1
        t = self._step
        drops_before = self.dropped_cluster_steps
        self.trace("embed_tokens", -1, "dispatch", t)
        x = self._embed(self.params, tokens_dev)
        act_dev = jnp.asarray(active)
        kv = state.kv
        new_hot: List[Dict[str, jax.Array]] = []
        nxt = self._launch_rank(0, kv, x, act_dev, t)
        for l in range(self.L):
            ctx, idx_r, live = nxt
            # the paper's CPU control plane: translating retrieved cluster
            # ids through the cache mapping tables needs them on host. The
            # readback was started asynchronously at dispatch time, so this
            # waits only for the transfer remainder.
            self.trace("readback_ids", l, "sync", t)
            ids = np.asarray(idx_r)  # retrolint: sync(per-layer id readback)
            self.trace("translate", l, "host", t)
            idx_slots, valid, mk, mv, mp = self._translate(l, ids, active)
            if self.pending_adm[l] is None:     # warm cache: staging only
                self.trace("cache_stage", l, "dispatch", t)
                self.cache_k[l], self.cache_v[l], self.cache_p[l] = \
                    self._cache_stage(self.cache_k[l], self.cache_v[l],
                                      self.cache_p[l], jnp.asarray(mk),
                                      jnp.asarray(mv), jnp.asarray(mp))
            else:
                adm_slots, adm_k, adm_v, adm_p = self.pending_adm[l]
                self.trace("cache_upd", l, "dispatch", t)
                self.cache_k[l], self.cache_v[l], self.cache_p[l] = \
                    self._cache_upd(self.cache_k[l], self.cache_v[l],
                                    self.cache_p[l], jnp.asarray(adm_slots),
                                    jnp.asarray(adm_k), jnp.asarray(adm_v),
                                    jnp.asarray(adm_p), jnp.asarray(mk),
                                    jnp.asarray(mv), jnp.asarray(mp))
            self.trace("attend_fn", l, "dispatch", t)
            x = self._attend(self._layers[l], self._windows[l], live, x, ctx,
                             self.cache_k[l], self.cache_v[l],
                             self.cache_p[l], jnp.asarray(idx_slots),
                             jnp.asarray(valid))
            new_hot.append(live)
            if l + 1 < self.L:      # pipeline: next rank before this drain
                nxt = self._launch_rank(l + 1, kv, x, act_dev, t)
            queued = self._drain_admissions(l, active)  # off the hot path
            self.trace("drain_admissions", l, "host", t, queued=queued)
        self.trace("unembed_logits", -1, "dispatch", t)
        logits = self._unembed(self.params, x)
        if self.dropped_cluster_steps > drops_before:
            self.degraded_steps += 1
        kv = kv._replace(**{f: jnp.stack([h[f] for h in new_hot])
                            for f in HOT_FIELDS})
        return logits, state._replace(kv=kv)

    # -------------------------------------------------------------- flush
    def flush(self, state, rows):               # retrolint: hot
        """Decode-time index update: meta entries on device, payload blocks
        appended to the host stores at each flushed row's cluster offset."""
        self._step += 1                 # own schedule epoch (between steps)
        kv = state.kv
        live = {f: getattr(kv, f) for f in LIVE_FIELDS}
        self.trace("offload_flush", -1, "dispatch", self._step)
        new_live, res = self._flush(live, jnp.asarray(rows))
        # sanctioned syncs: flushed payload blocks append to the HOST stores,
        # once per update_segment decoded tokens, not per step
        self.trace("readback_flush", -1, "sync", self._step)
        rk = np.asarray(res.k_store)  # retrolint: sync(flush block readback)
        rv = np.asarray(res.v_store)  # retrolint: sync(flush block readback)
        rp = np.asarray(res.pos_store)  # retrolint: sync(flush block readback)
        self.trace("host_flush", -1, "host", self._step)
        k_new = rk.shape[3]
        for b in np.where(rows)[0]:
            off = int(self.ncl[b])
            for l in range(self.L):
                if self.bufs[l][b] is None:
                    continue
                for h in range(self.H):
                    # store_rows, not a raw slice write: the flush must
                    # refresh the per-row crc32s or every later fetch of
                    # these clusters would read back as corruption
                    self.bufs[l][b][h].store_rows(
                        off, self._pack(rk[l, b, h], rv[l, b, h], rp[l, b, h]))
            self.ncl[b] += k_new
        return state._replace(kv=kv._replace(**new_live))

    # ------------------------------------------------------------- stats
    def export_stats(self, metrics: "ServeMetrics") -> None:
        metrics.cache.merge(self.retired)
        for per_layer in self.bufs:
            for row in per_layer:
                if row is not None:
                    for buf in row:
                        metrics.cache.merge(buf.stats)
        metrics.degraded_steps += self.degraded_steps
        metrics.dropped_cluster_steps += self.dropped_cluster_steps


class ServeEngine:
    """``serve(requests, batch_size)`` — continuous scheduler over a slot
    batch. ``max_context`` pins the decode geometry (zone plan / cluster-store
    capacity); all requests served by one engine share it, so a request's
    outputs are independent of what else shares the batch (a solo run at
    batch_size=1 reproduces them token-for-token, under either admission
    mode). ``prefill_chunk`` sets the chunked-admission chunk size;
    ``prefill_bucket`` > 1 right-pads blocking-mode prompts up to a multiple,
    trading a masked prefill for fewer compiled shapes. ``attn_impl`` selects
    the retro decode-attention implementation ("jnp" reference or "fused"
    gather-free paged kernel); None defers to ``cfg.retro.attn_impl``."""

    def __init__(self, cfg: ModelConfig, params, *, runtime: str = "retro",
                 gen_headroom: int = 1024, temperature: float = 0.0,
                 max_context: Optional[int] = None, prefill_bucket: int = 1,
                 admission: str = "chunked", prefill_chunk: int = 256,
                 attn_impl: Optional[str] = None,
                 offload: Optional[bool] = None,
                 cache_clusters: Optional[int] = None,
                 cache_frac: Optional[float] = None,
                 cache_policy: Optional[str] = None,
                 fault_profile: Optional[Any] = None,
                 fetch_deadline_s: Optional[float] = None,
                 fetch_retries: int = 2,
                 fetch_backoff_s: float = 1e-3,
                 max_decode_steps: Optional[int] = None):
        if admission not in ("chunked", "blocking"):
            raise ValueError(f"unknown admission mode {admission!r}")
        from repro.core.attention import resolve_attn_impl
        self.attn_impl = resolve_attn_impl(attn_impl or cfg.retro.attn_impl)
        self.cfg = cfg
        self.params = params
        self.runtime = runtime
        self.gen_headroom = gen_headroom
        self.temperature = temperature
        self.max_context = max_context
        self.prefill_bucket = max(1, prefill_bucket)
        self.admission = admission
        self.prefill_chunk = max(1, prefill_chunk)
        retro = cfg.retro
        self.offload = retro.offload if offload is None else offload
        if self.offload and not M.supports_offload(cfg, runtime):
            raise ValueError(
                "host-offload serving requires the retro runtime on an "
                f"attention family, got runtime={runtime!r} "
                f"family={cfg.family!r}")
        self.cache_clusters = retro.cache_clusters if cache_clusters is None \
            else cache_clusters
        self.cache_frac = retro.cache_frac if cache_frac is None \
            else cache_frac
        self.cache_policy = cache_policy or retro.cache_policy
        # retrofault knobs (offload data plane; inert on the direct path):
        # fault_profile accepts a FaultProfile or a "transient=0.2,seed=3"
        # CLI spec string; fetch_deadline_s is the per-translate-call virtual
        # budget; max_decode_steps is the per-request watchdog (any path)
        if isinstance(fault_profile, str):
            fault_profile = FaultProfile.parse(fault_profile)
        self.fault_profile = fault_profile
        self.fetch_deadline_s = fetch_deadline_s
        self.fetch_retries = fetch_retries
        self.fetch_backoff_s = fetch_backoff_s
        self.max_decode_steps = max_decode_steps
        self._prefill_jit: Dict[Any, Any] = {}
        self._decode_jit: Dict[Any, Any] = {}
        self._chunk_jit: Dict[Any, Any] = {}
        self._finalize_jit: Dict[Any, Any] = {}
        self._offload_jit: Dict[Any, Any] = {}
        def graft(big, small, slot):
            return jax.tree.map(
                lambda b, s: jax.lax.dynamic_update_slice_in_dim(
                    b, s.astype(b.dtype), slot, axis=1), big, small)

        # sample ON DEVICE: the decode loop only ever moves (B,) token ids to
        # host, never the (B, vocab) logits (at production vocab sizes that
        # transfer would dominate the step).
        def argmax_ids(lg):
            return jnp.argmax(lg, axis=-1).astype(jnp.int32)

        def categorical_ids(key, lg, temp):
            return jax.random.categorical(key, lg / temp).astype(jnp.int32)

        # scatter freshly admitted first tokens into the device token vector
        def merge_tokens(toks, upd, mask):
            return jnp.where(mask, upd, toks)

        self._graft = jax.jit(graft, donate_argnums=(0,))
        self._argmax = jax.jit(argmax_ids)
        self._categorical = jax.jit(categorical_ids)
        self._merge_tokens = jax.jit(merge_tokens)

    # ------------------------------------------------------------- compiled fns
    def _bucket(self, L: int) -> int:
        retro = self.cfg.retro
        if self.cfg.family not in ATTN_FAMILIES:
            return L        # recurrent prefills consume pads: compile exact
        if L < retro.sink + retro.local:
            return L        # too short to mask a ragged tail; compile exact
        b = self.prefill_bucket
        return L if b <= 1 else ((L + b - 1) // b) * b

    def _prefill_fn(self, seq_len: int, max_ctx: int):
        key = (seq_len, max_ctx)
        if key not in self._prefill_jit:
            cfg, rt, gh = self.cfg, self.runtime, self.gen_headroom
            plan = plan_zones(max_ctx, cfg.retro, gh) \
                if cfg.family != "ssm" else None
            ragged = cfg.family in ATTN_FAMILIES

            @jax.jit
            def prefill(params, batch, lengths):
                return M.apply_prefill(params, cfg, batch, runtime=rt,
                                       plan=plan, gen_headroom=gh,
                                       lengths=lengths if ragged else None,
                                       cache_len=max_ctx + gh)

            self._prefill_jit[key] = prefill
        return self._prefill_jit[key]

    def _chunk_fns(self, max_ctx: int):
        """ONE compiled prefill shape per engine geometry: every prompt is
        consumed as right-padded (1, prefill_chunk) chunks. The vlm variant
        additionally threads the request's patch embeddings (one compile per
        distinct patch shape)."""
        if max_ctx not in self._chunk_jit:
            cfg, rt = self.cfg, self.runtime

            @partial(jax.jit, donate_argnums=(1,))
            def chunk(params, cstate, toks, clen):
                return M.apply_prefill_chunk(params, cfg, {"tokens": toks},
                                             cstate, runtime=rt,
                                             chunk_lens=clen)

            @partial(jax.jit, donate_argnums=(1,))
            def chunk_pe(params, cstate, toks, clen, pe):
                return M.apply_prefill_chunk(
                    params, cfg, {"tokens": toks, "patch_embeds": pe},
                    cstate, runtime=rt, chunk_lens=clen)

            self._chunk_jit[max_ctx] = (chunk, chunk_pe)
        return self._chunk_jit[max_ctx]

    def _finalize_fn(self, total_len: int, max_ctx: int):
        """Finalize + graft one admitted slot. Per-prompt-length entries are
        cheap (tail clustering + scatter) — the expensive compiled shape, the
        chunk forward, is shared. In offload mode the finalized single-slot
        state is ALSO returned: it is the source of the slot's device->host
        store transfer (``_OffloadPlane.admit_slot``)."""
        key = (total_len, max_ctx, self.offload)
        if key not in self._finalize_jit:
            cfg, rt = self.cfg, self.runtime
            with_st1 = self.offload

            @partial(jax.jit, donate_argnums=(0,))
            def fin(big, cstate, slot):
                st1 = M.finalize_prefill_chunk(cfg, cstate, runtime=rt,
                                               total_len=total_len)
                big = jax.tree.map(
                    lambda b, s: jax.lax.dynamic_update_slice_in_dim(
                        b, s.astype(b.dtype), slot, axis=1), big, st1)
                return (big, st1) if with_st1 else big

            self._finalize_jit[key] = fin
        return self._finalize_jit[key]

    def _resolve_cache_clusters(self, m_max: int) -> int:
        """Device block-cache slots: absolute override or a fraction of the
        cluster-store size — clamped to [1, m_max] (tiny ``int(frac * n)``
        configs must round up to a one-slot cache, never zero)."""
        c = self.cache_clusters if self.cache_clusters > 0 \
            else int(self.cache_frac * m_max)
        return max(1, min(c, m_max))

    def _offload_fns(self, B: int, max_ctx: int, C: int, r: int):
        """Compiled pieces of the offload decode step, cached per engine
        geometry: (embed, rank, attend, unembed, cache_update, flush)."""
        key = (B, max_ctx, C, r)
        if key not in self._offload_jit:
            cfg = self.cfg
            plan = plan_zones(max_ctx, cfg.retro, self.gen_headroom)
            impl = self.attn_impl
            (embed, rank, attend, unembed, flush) = M.offload_decode_fns(cfg)

            def embed_tokens(p, t):
                return embed(p, cfg, t)

            # ``live`` is donated: the caller rebinds it from the result
            # (decode_step), so the per-layer hot fields update in place
            # instead of paying a defensive copy every step/layer
            @partial(jax.jit, donate_argnums=(2,))
            def rank_fn(lp, window, live, x, active):
                return rank(lp, window, cfg, live, x, plan=plan,
                            active=active)

            @jax.jit
            def attend_fn(lp, window, live, x, ctx, ck, cv, cp, idx, valid):
                return attend(lp, window, cfg, live, x, ctx, ck, cv, cp, idx,
                              valid, plan=plan, attn_impl=impl)

            def unembed_logits(p, x):
                return unembed(p, cfg, x)

            def cache_stage(ck, cv, cp, miss_k, miss_v, miss_p):
                # this step's misses stage into the tail [C, C + r)
                def stage(c, m):
                    return jax.lax.dynamic_update_slice(
                        c, m.astype(c.dtype), (C,) + (0,) * (m.ndim - 1))
                ss = jax.vmap(jax.vmap(stage))
                return ss(ck, miss_k), ss(cv, miss_v), ss(cp, miss_p)

            @partial(jax.jit, donate_argnums=(0, 1, 2))
            def cache_upd(ck, cv, cp, adm_slots, adm_k, adm_v, adm_p,
                          miss_k, miss_v, miss_p):
                # previous step's deferred admissions mirror into [0, C)
                # (OOB-padded slot ids are dropped writes)
                def row(c, s, pay):
                    return c.at[s].set(pay.astype(c.dtype), mode="drop")
                rr = jax.vmap(jax.vmap(row))
                ck, cv, cp = rr(ck, adm_slots, adm_k), \
                    rr(cv, adm_slots, adm_v), rr(cp, adm_slots, adm_p)
                return cache_stage(ck, cv, cp, miss_k, miss_v, miss_p)

            # the stacked live fields are donated: flush's caller replaces
            # them wholesale (``kv._replace(**new_live)``) and never touches
            # the old references again
            @partial(jax.jit, donate_argnums=(0,))
            def offload_flush(live_stacked, rows):
                return flush(cfg, live_stacked, rows)

            self._offload_jit[key] = (
                jax.jit(embed_tokens),
                rank_fn,
                attend_fn,
                jax.jit(unembed_logits),
                cache_upd,
                # warm-cache fast path: no admissions queued, staging only
                jax.jit(cache_stage, donate_argnums=(0, 1, 2)),
                offload_flush)
        return self._offload_jit[key]

    def _decode_fns(self, batch_size: int, max_ctx: int):
        key = (batch_size, max_ctx)
        if key not in self._decode_jit:
            cfg, rt, gh = self.cfg, self.runtime, self.gen_headroom
            impl = self.attn_impl
            plan = plan_zones(max_ctx, cfg.retro, gh) \
                if cfg.family != "ssm" else None

            @partial(jax.jit, donate_argnums=(1,))
            def decode(params, state, token, active):
                return M.apply_decode(params, cfg, state, token, runtime=rt,
                                      plan=plan, seq_len=max_ctx,
                                      gen_headroom=gh, active=active,
                                      attn_impl=impl)

            @partial(jax.jit, donate_argnums=(0,))
            def flush(state):
                return M.flush_state(cfg, state, runtime=rt)

            self._decode_jit[key] = (decode, flush)
        return self._decode_jit[key]

    # ---------------------------------------------------------------- serving
    def _sample_dev(self, logits, key):
        """Device logits -> device (B,) token ids (no host transfer)."""
        if self.temperature <= 0:
            return self._argmax(logits)
        return self._categorical(key, logits, jnp.float32(self.temperature))

    def _sample(self, logits, key) -> np.ndarray:   # retrolint: hot
        """Device logits -> host (B,) token ids (blocks until ready). Used
        only for coalesced first-token sampling: ONE readback per admission
        round; the decode loop samples with ``_sample_dev`` (no sync)."""
        return np.asarray(  # retrolint: sync(coalesced first-token readback)
            self._sample_dev(logits, key)).astype(np.int64)

    def serve(self, requests: List[Request], batch_size: int,  # retrolint: hot
              seed: int = 0) -> ServeMetrics:
        """Serve a FIFO queue through ``batch_size`` continuous slots."""
        cfg, rt = self.cfg, self.runtime
        assert requests
        max_ctx = self.max_context or max(
            self._bucket(len(r.prompt)) for r in requests)
        min_len = cfg.retro.sink + 1 \
            if rt == "retro" and cfg.family != "ssm" else 1
        for r in requests:
            if not min_len <= len(r.prompt) <= max_ctx:
                raise ValueError(
                    f"prompt length {len(r.prompt)} outside "
                    f"[{min_len}, {max_ctx}]")
        B = batch_size
        # chunk attention is exact: configs that opt into block-sparse
        # prefill keep the monolithic (sparse) admission path
        chunked = self.admission == "chunked" \
            and M.supports_chunked_prefill(cfg, rt) \
            and cfg.sparse_prefill_blocks == 0
        plane = _OffloadPlane(self, B, max_ctx) if self.offload else None
        decode, flush = (None, None) if self.offload \
            else self._decode_fns(B, max_ctx)
        state = M.make_serve_state(cfg, B, max_ctx, runtime=rt,
                                   gen_headroom=self.gen_headroom,
                                   zero_fill=True)
        lbuf = local_buffer_size(cfg.retro)
        use_flush = rt == "retro" and cfg.family != "ssm"

        queue = deque(requests)
        slots: List[Optional[Request]] = [None] * B
        admitting: List[Optional[_Admission]] = [None] * B
        active = np.zeros(B, bool)
        staged = np.zeros(B, np.int64)      # host mirror of local_len (retro)
        slot_steps = np.zeros(B, np.int64)  # watchdog: decode steps per slot
        admit_t = np.zeros(B, float)
        tokens_dev = jnp.zeros((B,), jnp.int32)     # device-resident ids
        prev_sampled = None                 # step t's device ids (unsynced)
        prev_snapshot: List[Optional[Request]] = [None] * B
        last_deliver_t: Optional[float] = None
        last_deliver: set = set()
        metrics = ServeMetrics(n_slots=B)
        key = jax.random.PRNGKey(seed)
        t_start = time.perf_counter()

        def finish(i: int, req: Request, status: str = "ok"):
            req.done = True
            req.status = status
            dt = time.perf_counter() - admit_t[i]
            n_decode = len(req.out_tokens) - 1   # first token is prefill's
            req.decode_tps = n_decode / dt if dt > 0 and n_decode > 0 else 0.0
            # a max_new_tokens=1 request decodes ZERO tokens — recording its
            # 0.0 tok/s would drag down mean/percentile request throughput,
            # so the sample is skipped (the request still counts everywhere
            # else: TTFT, tokens_out)
            if n_decode > 0:
                metrics.request_tps.append(req.decode_tps)
            slots[i] = None
            active[i] = False

        while queue or active.any() or any(a is not None for a in admitting) \
                or prev_sampled is not None:
            # ---- admission: one prefill chunk per admitting slot ----------
            t0 = time.perf_counter()
            completed: List[Tuple[int, _Admission]] = []
            for i in range(B):
                if not chunked:
                    if active[i] or slots[i] is not None or not queue:
                        continue
                    req = queue.popleft()
                    L = len(req.prompt)
                    S_b = min(self._bucket(L), max_ctx)
                    assert S_b >= L
                    toks = np.zeros((1, S_b), np.int32)
                    toks[0, :L] = req.prompt
                    batch = {"tokens": jnp.asarray(toks)}
                    if req.extra:
                        batch.update(req.extra)
                    prefill = self._prefill_fn(S_b, max_ctx)
                    logits, st1 = prefill(self.params, batch,
                                          jnp.asarray([L], jnp.int32))
                    state = self._graft(state, st1, jnp.asarray(i, jnp.int32))
                    if plane is not None:   # device->host store offload
                        plane.admit_slot(i, st1)
                    completed.append((i, _Admission(req=req, logits=logits,
                                                    consumed=L)))
                    continue
                if admitting[i] is None and not active[i] \
                        and slots[i] is None and queue:
                    req = queue.popleft()
                    admitting[i] = _Admission(
                        req=req,
                        cstate=M.make_prefill_chunk_state(
                            cfg, 1, max_ctx, runtime=rt,
                            chunk=self.prefill_chunk,
                            gen_headroom=self.gen_headroom))
                adm = admitting[i]
                if adm is None:
                    continue
                L, C = len(adm.req.prompt), self.prefill_chunk
                n = min(C, L - adm.consumed)
                toks = np.zeros((1, C), np.int32)
                toks[0, :n] = adm.req.prompt[adm.consumed:adm.consumed + n]
                chunk, chunk_pe = self._chunk_fns(max_ctx)
                extra = adm.req.extra or {}
                if set(extra) == {"patch_embeds"}:
                    adm.logits, adm.cstate = chunk_pe(
                        self.params, adm.cstate, jnp.asarray(toks),
                        jnp.asarray([n], jnp.int32), extra["patch_embeds"])
                elif extra:     # uncompiled fallback for exotic extras
                    adm.logits, adm.cstate = M.apply_prefill_chunk(
                        self.params, cfg,
                        {"tokens": jnp.asarray(toks), **extra},
                        adm.cstate, runtime=rt,
                        chunk_lens=jnp.asarray([n], jnp.int32))
                else:
                    adm.logits, adm.cstate = chunk(
                        self.params, adm.cstate, jnp.asarray(toks),
                        jnp.asarray([n], jnp.int32))
                adm.consumed += n
                if adm.consumed >= L:
                    fin = self._finalize_fn(L, max_ctx)
                    if plane is not None:
                        state, st1 = fin(state, adm.cstate,
                                         jnp.asarray(i, jnp.int32))
                        plane.admit_slot(i, st1)    # device->host offload
                    else:
                        state = fin(state, adm.cstate,
                                    jnp.asarray(i, jnp.int32))
                    adm.cstate = None
                    admitting[i] = None
                    completed.append((i, adm))

            if completed:
                # coalesced first-token sampling: ONE host sync for every
                # request admitted this iteration
                key, sub = jax.random.split(key)
                stacked = jnp.concatenate([a.logits for _, a in completed], 0)
                first = self._sample(stacked, sub)      # blocks until ready
                now = time.perf_counter()
                upd = np.zeros(B, np.int32)
                mask = np.zeros(B, bool)
                for (i, adm), tok in zip(completed, first):
                    req = adm.req
                    req.ttft_s = now - t_start
                    req.out_tokens.append(int(tok))
                    metrics.tokens_out += 1
                    metrics.ttft_s.append(req.ttft_s)
                    admit_t[i] = now
                    slots[i] = req
                    active[i] = True
                    slot_steps[i] = 0
                    upd[i], mask[i] = tok, True
                    # device local_len after admission: chunked finalize uses
                    # the true length; a padded blocking prefill uses S_b, but
                    # _bucket only pads prompts with L >= sink + local, where
                    # both give exactly ``local`` — the mirror matches either
                    staged[i] = min(cfg.retro.local,
                                    max(adm.consumed - cfg.retro.sink, 0))
                    if len(req.out_tokens) >= req.max_new_tokens:
                        finish(i, req)
                tokens_dev = self._merge_tokens(tokens_dev, jnp.asarray(upd),
                                                jnp.asarray(mask))
            metrics.prefill_s += time.perf_counter() - t0

            # ---- one decode step over the whole slot batch -----------------
            # Dispatch step t+1 BEFORE syncing step t's ids: sampling stays on
            # device and the ids ride back one step late (the loop's only
            # decode-path host sync).
            t0 = time.perf_counter()
            did_decode = False
            if active.any():
                key, sub = jax.random.split(key)
                if plane is not None:
                    logits, state = plane.decode_step(state, tokens_dev,
                                                      active)
                else:
                    logits, state = decode(self.params, state, tokens_dev,
                                           jnp.asarray(active))
                new_sampled = self._sample_dev(logits, sub)  # device, no sync
                snapshot = [slots[i] if active[i] else None for i in range(B)]
                metrics.steps += 1
                metrics.occupied_slot_steps += int(active.sum())
                staged[active] += 1
                slot_steps[active] += 1
                did_decode = True
                # unrecoverable transport fault: finish ONLY the affected
                # requests with a structured error status — no engine-wide
                # quarantine, the remaining slots keep serving. The killed
                # request's in-flight token is dropped by the lagged harvest
                # below (slots[i] no longer holds it).
                if plane is not None and plane.failed_slots:
                    for i in sorted(plane.failed_slots):
                        if slots[i] is not None:
                            finish(i, slots[i], status="error")
                    plane.failed_slots.clear()
                # per-request watchdog: a request whose stop condition never
                # triggers cannot occupy a slot forever
                if self.max_decode_steps is not None:
                    for i in range(B):
                        if active[i] and slot_steps[i] >= self.max_decode_steps:
                            finish(i, slots[i], status="timeout")

            # ---- harvest step t's ids (one step lagged) --------------------
            if prev_sampled is not None:
                # the decode loop's ONLY sync: step t's ids, harvested one
                # step late (step t+1 is already dispatched above)
                ids = np.asarray(prev_sampled)  # retrolint: sync(lagged id harvest)
                now = time.perf_counter()
                delivered = set()
                for i, req in enumerate(prev_snapshot):
                    if req is None or slots[i] is not req or req.done:
                        continue        # freed/re-admitted: speculative token
                    delivered.add(id(req))
                    req.out_tokens.append(int(ids[i]))
                    metrics.tokens_out += 1
                    if len(req.out_tokens) >= req.max_new_tokens:
                        finish(i, req)
                if delivered:
                    if last_deliver_t is not None and (delivered
                                                       & last_deliver):
                        metrics.step_s.append(now - last_deliver_t)
                    last_deliver_t, last_deliver = now, delivered
            if did_decode:
                prev_sampled, prev_snapshot = new_sampled, snapshot
                tokens_dev = new_sampled
            else:
                prev_sampled, prev_snapshot = None, [None] * B
            metrics.decode_s += time.perf_counter() - t0

            # ---- per-row masked index update (off the per-step hot path) ---
            if use_flush and (staged >= lbuf).any():
                rows = staged >= lbuf
                if plane is not None:
                    state = plane.flush(state, rows)
                else:
                    state = flush(state)
                staged[rows] -= cfg.retro.update_segment
        if plane is not None:
            plane.export_stats(metrics)
            self._last_plane = plane        # inspection hook (tests)
        return metrics

    def run_wave(self, requests: List[Request],
                 extra_batch: Optional[Dict] = None,
                 seed: int = 0) -> ServeMetrics:
        """Back-compat: serve one batch of requests with one slot each."""
        if extra_batch:
            for i, r in enumerate(requests):
                r.extra = {k: v[i:i + 1] for k, v in extra_batch.items()}
        return self.serve(requests, batch_size=len(requests), seed=seed)
