"""Continuous-batching serving engine with chunked admission and a sync-free
decode loop.

The decode loop runs a fixed number of SLOTS (the decode batch). Each slot
holds at most one in-flight request; finished requests free their slot and
queued requests are admitted mid-stream. Per-request wave-index bookkeeping
(``length``/``local_len``/``n_clusters`` are (B,) arrays) lets ragged
requests sit at different positions in one batch; staging-buffer flushes are
per-row masked, so rows flush on their own schedule.

Admission (``admission="chunked"``, the default where the family supports
it): a request's prompt is consumed one fixed-size chunk per scheduler
iteration, interleaved between decode steps, so in-flight decodes never stall
longer than one chunk. One compiled chunk shape (the final chunk is
right-padded and masked) replaces the per-bucket prefill jit cache; the wave
index is built incrementally (``prefill_append_chunk``) and finalized
bit-identically to the monolithic build. ``admission="blocking"`` keeps the
monolithic per-slot prefill (bucketed/jit-cached) for comparison and for the
pass-through families (encdec/hybrid/ssm), which fall back automatically.

The decode loop issues NO host sync between consecutive decode dispatches:
tokens are sampled on device and fed device-to-device into the next step; the
ids of step t are read back (the loop's only sync) only after step t+1 has
been dispatched. Completion is therefore detected one step late — the extra
speculative token of a just-finished request is dropped on harvest, and its
slot's state is overwritten by the next admission graft. First tokens of all
requests admitted in the same iteration are sampled with ONE coalesced
device->host readback.

Metrics are per-request (TTFT, decode tok/s) plus engine-level slot occupancy,
aggregate throughput, and inter-token latency (p50/p99 over gaps between
consecutive token deliveries of continuing requests — the decode-interference
signal chunked admission exists to shrink). Only real requests count: free
slots produce logits that are never sampled, so padding can't inflate
``tokens_out``.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from functools import partial
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.wave_index import local_buffer_size
from repro.core.zones import plan_zones
from repro.models import model as M
from repro.models.model import ATTN_FAMILIES


@dataclass
class Request:
    prompt: np.ndarray                  # (S,) int32
    max_new_tokens: int
    out_tokens: List[int] = field(default_factory=list)
    done: bool = False
    extra: Optional[Dict] = None        # per-request prefill extras (e.g. vlm)
    # ---- filled by the engine ----
    ttft_s: float = 0.0                 # enqueue -> first token
    decode_tps: float = 0.0             # this request's decode tokens/s


@dataclass
class ServeMetrics:
    """Aggregate serve metrics. Padding/free slots never contribute: only
    sampled tokens of real requests are counted."""
    prefill_s: float = 0.0
    decode_s: float = 0.0
    tokens_out: int = 0
    steps: int = 0                      # decode steps executed
    occupied_slot_steps: int = 0        # sum over steps of active slots
    n_slots: int = 0
    ttft_s: List[float] = field(default_factory=list)
    request_tps: List[float] = field(default_factory=list)
    # gaps between consecutive token deliveries of continuing requests —
    # includes any admission work scheduled in between (the interference term)
    step_s: List[float] = field(default_factory=list)

    @property
    def decode_tps(self) -> float:
        return self.tokens_out / max(self.decode_s, 1e-9)

    @property
    def slot_occupancy(self) -> float:
        return self.occupied_slot_steps / max(self.steps * self.n_slots, 1)

    @property
    def itl_p50_s(self) -> float:
        return float(np.percentile(self.step_s, 50)) if self.step_s else 0.0

    @property
    def itl_p99_s(self) -> float:
        return float(np.percentile(self.step_s, 99)) if self.step_s else 0.0

    @property
    def ttft_p50_s(self) -> float:
        return float(np.percentile(self.ttft_s, 50)) if self.ttft_s else 0.0

    @property
    def ttft_p99_s(self) -> float:
        return float(np.percentile(self.ttft_s, 99)) if self.ttft_s else 0.0


# back-compat alias (pre-continuous engines returned per-wave metrics)
WaveMetrics = ServeMetrics


@dataclass
class _Admission:
    """One slot's in-progress chunked admission (or a just-finished blocking
    prefill awaiting its coalesced first-token sample)."""
    req: Request
    cstate: Any = None                  # PrefillChunkState (chunked mode)
    consumed: int = 0
    logits: Any = None                  # device logits of the last chunk


class ServeEngine:
    """``serve(requests, batch_size)`` — continuous scheduler over a slot
    batch. ``max_context`` pins the decode geometry (zone plan / cluster-store
    capacity); all requests served by one engine share it, so a request's
    outputs are independent of what else shares the batch (a solo run at
    batch_size=1 reproduces them token-for-token, under either admission
    mode). ``prefill_chunk`` sets the chunked-admission chunk size;
    ``prefill_bucket`` > 1 right-pads blocking-mode prompts up to a multiple,
    trading a masked prefill for fewer compiled shapes. ``attn_impl`` selects
    the retro decode-attention implementation ("jnp" reference or "fused"
    gather-free paged kernel); None defers to ``cfg.retro.attn_impl``."""

    def __init__(self, cfg: ModelConfig, params, *, runtime: str = "retro",
                 gen_headroom: int = 1024, temperature: float = 0.0,
                 max_context: Optional[int] = None, prefill_bucket: int = 1,
                 admission: str = "chunked", prefill_chunk: int = 256,
                 attn_impl: Optional[str] = None):
        if admission not in ("chunked", "blocking"):
            raise ValueError(f"unknown admission mode {admission!r}")
        from repro.core.attention import resolve_attn_impl
        self.attn_impl = resolve_attn_impl(attn_impl or cfg.retro.attn_impl)
        self.cfg = cfg
        self.params = params
        self.runtime = runtime
        self.gen_headroom = gen_headroom
        self.temperature = temperature
        self.max_context = max_context
        self.prefill_bucket = max(1, prefill_bucket)
        self.admission = admission
        self.prefill_chunk = max(1, prefill_chunk)
        self._prefill_jit: Dict[Any, Any] = {}
        self._decode_jit: Dict[Any, Any] = {}
        self._chunk_jit: Dict[Any, Any] = {}
        self._finalize_jit: Dict[Any, Any] = {}
        self._graft = jax.jit(
            lambda big, small, slot: jax.tree.map(
                lambda b, s: jax.lax.dynamic_update_slice_in_dim(
                    b, s.astype(b.dtype), slot, axis=1), big, small),
            donate_argnums=(0,))
        # sample ON DEVICE: the decode loop only ever moves (B,) token ids to
        # host, never the (B, vocab) logits (at production vocab sizes that
        # transfer would dominate the step).
        self._argmax = jax.jit(
            lambda lg: jnp.argmax(lg, axis=-1).astype(jnp.int32))
        self._categorical = jax.jit(
            lambda key, lg, temp: jax.random.categorical(
                key, lg / temp).astype(jnp.int32))
        # scatter freshly admitted first tokens into the device token vector
        self._merge_tokens = jax.jit(
            lambda toks, upd, mask: jnp.where(mask, upd, toks))

    # ------------------------------------------------------------- compiled fns
    def _bucket(self, L: int) -> int:
        retro = self.cfg.retro
        if self.cfg.family not in ATTN_FAMILIES:
            return L        # recurrent prefills consume pads: compile exact
        if L < retro.sink + retro.local:
            return L        # too short to mask a ragged tail; compile exact
        b = self.prefill_bucket
        return L if b <= 1 else ((L + b - 1) // b) * b

    def _prefill_fn(self, seq_len: int, max_ctx: int):
        key = (seq_len, max_ctx)
        if key not in self._prefill_jit:
            cfg, rt, gh = self.cfg, self.runtime, self.gen_headroom
            plan = plan_zones(max_ctx, cfg.retro, gh) \
                if cfg.family != "ssm" else None
            ragged = cfg.family in ATTN_FAMILIES

            @jax.jit
            def prefill(params, batch, lengths):
                return M.apply_prefill(params, cfg, batch, runtime=rt,
                                       plan=plan, gen_headroom=gh,
                                       lengths=lengths if ragged else None,
                                       cache_len=max_ctx + gh)

            self._prefill_jit[key] = prefill
        return self._prefill_jit[key]

    def _chunk_fns(self, max_ctx: int):
        """ONE compiled prefill shape per engine geometry: every prompt is
        consumed as right-padded (1, prefill_chunk) chunks. The vlm variant
        additionally threads the request's patch embeddings (one compile per
        distinct patch shape)."""
        if max_ctx not in self._chunk_jit:
            cfg, rt = self.cfg, self.runtime

            @partial(jax.jit, donate_argnums=(1,))
            def chunk(params, cstate, toks, clen):
                return M.apply_prefill_chunk(params, cfg, {"tokens": toks},
                                             cstate, runtime=rt,
                                             chunk_lens=clen)

            @partial(jax.jit, donate_argnums=(1,))
            def chunk_pe(params, cstate, toks, clen, pe):
                return M.apply_prefill_chunk(
                    params, cfg, {"tokens": toks, "patch_embeds": pe},
                    cstate, runtime=rt, chunk_lens=clen)

            self._chunk_jit[max_ctx] = (chunk, chunk_pe)
        return self._chunk_jit[max_ctx]

    def _finalize_fn(self, total_len: int, max_ctx: int):
        """Finalize + graft one admitted slot. Per-prompt-length entries are
        cheap (tail clustering + scatter) — the expensive compiled shape, the
        chunk forward, is shared."""
        key = (total_len, max_ctx)
        if key not in self._finalize_jit:
            cfg, rt = self.cfg, self.runtime

            @partial(jax.jit, donate_argnums=(0,))
            def fin(big, cstate, slot):
                st1 = M.finalize_prefill_chunk(cfg, cstate, runtime=rt,
                                               total_len=total_len)
                return jax.tree.map(
                    lambda b, s: jax.lax.dynamic_update_slice_in_dim(
                        b, s.astype(b.dtype), slot, axis=1), big, st1)

            self._finalize_jit[key] = fin
        return self._finalize_jit[key]

    def _decode_fns(self, batch_size: int, max_ctx: int):
        key = (batch_size, max_ctx)
        if key not in self._decode_jit:
            cfg, rt, gh = self.cfg, self.runtime, self.gen_headroom
            impl = self.attn_impl
            plan = plan_zones(max_ctx, cfg.retro, gh) \
                if cfg.family != "ssm" else None

            @partial(jax.jit, donate_argnums=(1,))
            def decode(params, state, token, active):
                return M.apply_decode(params, cfg, state, token, runtime=rt,
                                      plan=plan, seq_len=max_ctx,
                                      gen_headroom=gh, active=active,
                                      attn_impl=impl)

            @partial(jax.jit, donate_argnums=(0,))
            def flush(state):
                return M.flush_state(cfg, state, runtime=rt)

            self._decode_jit[key] = (decode, flush)
        return self._decode_jit[key]

    # ---------------------------------------------------------------- serving
    def _sample_dev(self, logits, key):
        """Device logits -> device (B,) token ids (no host transfer)."""
        if self.temperature <= 0:
            return self._argmax(logits)
        return self._categorical(key, logits, jnp.float32(self.temperature))

    def _sample(self, logits, key) -> np.ndarray:
        """Device logits -> host (B,) token ids (blocks until ready)."""
        return np.asarray(self._sample_dev(logits, key)).astype(np.int64)

    def serve(self, requests: List[Request], batch_size: int,
              seed: int = 0) -> ServeMetrics:
        """Serve a FIFO queue through ``batch_size`` continuous slots."""
        cfg, rt = self.cfg, self.runtime
        assert requests
        max_ctx = self.max_context or max(
            self._bucket(len(r.prompt)) for r in requests)
        min_len = cfg.retro.sink + 1 \
            if rt == "retro" and cfg.family != "ssm" else 1
        for r in requests:
            if not min_len <= len(r.prompt) <= max_ctx:
                raise ValueError(
                    f"prompt length {len(r.prompt)} outside "
                    f"[{min_len}, {max_ctx}]")
        B = batch_size
        # chunk attention is exact: configs that opt into block-sparse
        # prefill keep the monolithic (sparse) admission path
        chunked = self.admission == "chunked" \
            and M.supports_chunked_prefill(cfg, rt) \
            and cfg.sparse_prefill_blocks == 0
        decode, flush = self._decode_fns(B, max_ctx)
        state = M.make_serve_state(cfg, B, max_ctx, runtime=rt,
                                   gen_headroom=self.gen_headroom,
                                   zero_fill=True)
        lbuf = local_buffer_size(cfg.retro)
        use_flush = rt == "retro" and cfg.family != "ssm"

        queue = deque(requests)
        slots: List[Optional[Request]] = [None] * B
        admitting: List[Optional[_Admission]] = [None] * B
        active = np.zeros(B, bool)
        staged = np.zeros(B, np.int64)      # host mirror of local_len (retro)
        admit_t = np.zeros(B, float)
        tokens_dev = jnp.zeros((B,), jnp.int32)     # device-resident ids
        prev_sampled = None                 # step t's device ids (unsynced)
        prev_snapshot: List[Optional[Request]] = [None] * B
        last_deliver_t: Optional[float] = None
        last_deliver: set = set()
        metrics = ServeMetrics(n_slots=B)
        key = jax.random.PRNGKey(seed)
        t_start = time.perf_counter()

        def finish(i: int, req: Request):
            req.done = True
            dt = time.perf_counter() - admit_t[i]
            n_decode = len(req.out_tokens) - 1   # first token is prefill's
            req.decode_tps = n_decode / dt if dt > 0 and n_decode > 0 else 0.0
            metrics.request_tps.append(req.decode_tps)
            slots[i] = None
            active[i] = False

        while queue or active.any() or any(a is not None for a in admitting) \
                or prev_sampled is not None:
            # ---- admission: one prefill chunk per admitting slot ----------
            t0 = time.perf_counter()
            completed: List[Tuple[int, _Admission]] = []
            for i in range(B):
                if not chunked:
                    if active[i] or slots[i] is not None or not queue:
                        continue
                    req = queue.popleft()
                    L = len(req.prompt)
                    S_b = min(self._bucket(L), max_ctx)
                    assert S_b >= L
                    toks = np.zeros((1, S_b), np.int32)
                    toks[0, :L] = req.prompt
                    batch = {"tokens": jnp.asarray(toks)}
                    if req.extra:
                        batch.update(req.extra)
                    prefill = self._prefill_fn(S_b, max_ctx)
                    logits, st1 = prefill(self.params, batch,
                                          jnp.asarray([L], jnp.int32))
                    state = self._graft(state, st1, jnp.asarray(i, jnp.int32))
                    completed.append((i, _Admission(req=req, logits=logits,
                                                    consumed=L)))
                    continue
                if admitting[i] is None and not active[i] \
                        and slots[i] is None and queue:
                    req = queue.popleft()
                    admitting[i] = _Admission(
                        req=req,
                        cstate=M.make_prefill_chunk_state(
                            cfg, 1, max_ctx, runtime=rt,
                            chunk=self.prefill_chunk,
                            gen_headroom=self.gen_headroom))
                adm = admitting[i]
                if adm is None:
                    continue
                L, C = len(adm.req.prompt), self.prefill_chunk
                n = min(C, L - adm.consumed)
                toks = np.zeros((1, C), np.int32)
                toks[0, :n] = adm.req.prompt[adm.consumed:adm.consumed + n]
                chunk, chunk_pe = self._chunk_fns(max_ctx)
                extra = adm.req.extra or {}
                if set(extra) == {"patch_embeds"}:
                    adm.logits, adm.cstate = chunk_pe(
                        self.params, adm.cstate, jnp.asarray(toks),
                        jnp.asarray([n], jnp.int32), extra["patch_embeds"])
                elif extra:     # uncompiled fallback for exotic extras
                    adm.logits, adm.cstate = M.apply_prefill_chunk(
                        self.params, cfg,
                        {"tokens": jnp.asarray(toks), **extra},
                        adm.cstate, runtime=rt,
                        chunk_lens=jnp.asarray([n], jnp.int32))
                else:
                    adm.logits, adm.cstate = chunk(
                        self.params, adm.cstate, jnp.asarray(toks),
                        jnp.asarray([n], jnp.int32))
                adm.consumed += n
                if adm.consumed >= L:
                    fin = self._finalize_fn(L, max_ctx)
                    state = fin(state, adm.cstate, jnp.asarray(i, jnp.int32))
                    adm.cstate = None
                    admitting[i] = None
                    completed.append((i, adm))

            if completed:
                # coalesced first-token sampling: ONE host sync for every
                # request admitted this iteration
                key, sub = jax.random.split(key)
                stacked = jnp.concatenate([a.logits for _, a in completed], 0)
                first = self._sample(stacked, sub)      # blocks until ready
                now = time.perf_counter()
                upd = np.zeros(B, np.int32)
                mask = np.zeros(B, bool)
                for (i, adm), tok in zip(completed, first):
                    req = adm.req
                    req.ttft_s = now - t_start
                    req.out_tokens.append(int(tok))
                    metrics.tokens_out += 1
                    metrics.ttft_s.append(req.ttft_s)
                    admit_t[i] = now
                    slots[i] = req
                    active[i] = True
                    upd[i], mask[i] = tok, True
                    # device local_len after admission: chunked finalize uses
                    # the true length; a padded blocking prefill uses S_b, but
                    # _bucket only pads prompts with L >= sink + local, where
                    # both give exactly ``local`` — the mirror matches either
                    staged[i] = min(cfg.retro.local,
                                    max(adm.consumed - cfg.retro.sink, 0))
                    if len(req.out_tokens) >= req.max_new_tokens:
                        finish(i, req)
                tokens_dev = self._merge_tokens(tokens_dev, jnp.asarray(upd),
                                                jnp.asarray(mask))
            metrics.prefill_s += time.perf_counter() - t0

            # ---- one decode step over the whole slot batch -----------------
            # Dispatch step t+1 BEFORE syncing step t's ids: sampling stays on
            # device and the ids ride back one step late (the loop's only
            # decode-path host sync).
            t0 = time.perf_counter()
            did_decode = False
            if active.any():
                key, sub = jax.random.split(key)
                logits, state = decode(self.params, state, tokens_dev,
                                       jnp.asarray(active))
                new_sampled = self._sample_dev(logits, sub)  # device, no sync
                snapshot = [slots[i] if active[i] else None for i in range(B)]
                metrics.steps += 1
                metrics.occupied_slot_steps += int(active.sum())
                staged[active] += 1
                did_decode = True

            # ---- harvest step t's ids (one step lagged) --------------------
            if prev_sampled is not None:
                ids = np.asarray(prev_sampled)               # the only sync
                now = time.perf_counter()
                delivered = set()
                for i, req in enumerate(prev_snapshot):
                    if req is None or slots[i] is not req or req.done:
                        continue        # freed/re-admitted: speculative token
                    delivered.add(id(req))
                    req.out_tokens.append(int(ids[i]))
                    metrics.tokens_out += 1
                    if len(req.out_tokens) >= req.max_new_tokens:
                        finish(i, req)
                if delivered:
                    if last_deliver_t is not None and (delivered
                                                       & last_deliver):
                        metrics.step_s.append(now - last_deliver_t)
                    last_deliver_t, last_deliver = now, delivered
            if did_decode:
                prev_sampled, prev_snapshot = new_sampled, snapshot
                tokens_dev = new_sampled
            else:
                prev_sampled, prev_snapshot = None, [None] * B
            metrics.decode_s += time.perf_counter() - t0

            # ---- per-row masked index update (off the per-step hot path) ---
            if use_flush and (staged >= lbuf).any():
                state = flush(state)
                staged[staged >= lbuf] -= cfg.retro.update_segment
        return metrics

    def run_wave(self, requests: List[Request],
                 extra_batch: Optional[Dict] = None,
                 seed: int = 0) -> ServeMetrics:
        """Back-compat: serve one batch of requests with one slot each."""
        if extra_batch:
            for i, r in enumerate(requests):
                r.extra = {k: v[i:i + 1] for k, v in extra_batch.items()}
        return self.serve(requests, batch_size=len(requests), seed=seed)
