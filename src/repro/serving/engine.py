"""Continuous-batching serving engine.

The decode loop runs a fixed number of SLOTS (the decode batch). Each slot
holds at most one in-flight request; finished requests free their slot and
queued requests are admitted mid-stream via a per-slot prefill whose state is
grafted into the shared decode batch. Per-request wave-index bookkeeping
(``length``/``local_len``/``n_clusters`` are (B,) arrays) lets ragged
requests sit at different positions in one batch; staging-buffer flushes are
per-row masked, so rows flush on their own schedule.

Ragged prompts are right-padded to a jit bucket and masked (the wave index
never stores a pad token; logits are read at each row's true last position),
so a handful of compiled prefill shapes serves arbitrary traffic.

Metrics are per-request (TTFT, decode tok/s) plus engine-level slot occupancy
and aggregate throughput. Only real requests count: free slots produce
logits that are never sampled, so padding can't inflate ``tokens_out``.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from functools import partial
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.wave_index import local_buffer_size
from repro.core.zones import plan_zones
from repro.models import model as M
from repro.models.model import ATTN_FAMILIES


@dataclass
class Request:
    prompt: np.ndarray                  # (S,) int32
    max_new_tokens: int
    out_tokens: List[int] = field(default_factory=list)
    done: bool = False
    extra: Optional[Dict] = None        # per-request prefill extras (e.g. vlm)
    # ---- filled by the engine ----
    ttft_s: float = 0.0                 # enqueue -> first token
    decode_tps: float = 0.0             # this request's decode tokens/s


@dataclass
class ServeMetrics:
    """Aggregate serve metrics. Padding/free slots never contribute: only
    sampled tokens of real requests are counted."""
    prefill_s: float = 0.0
    decode_s: float = 0.0
    tokens_out: int = 0
    steps: int = 0                      # decode steps executed
    occupied_slot_steps: int = 0        # sum over steps of active slots
    n_slots: int = 0
    ttft_s: List[float] = field(default_factory=list)
    request_tps: List[float] = field(default_factory=list)

    @property
    def decode_tps(self) -> float:
        return self.tokens_out / max(self.decode_s, 1e-9)

    @property
    def slot_occupancy(self) -> float:
        return self.occupied_slot_steps / max(self.steps * self.n_slots, 1)


# back-compat alias (pre-continuous engines returned per-wave metrics)
WaveMetrics = ServeMetrics


class ServeEngine:
    """``serve(requests, batch_size)`` — continuous scheduler over a slot
    batch. ``max_context`` pins the decode geometry (zone plan / cluster-store
    capacity); all requests served by one engine share it, so a request's
    outputs are independent of what else shares the batch (a solo run at
    batch_size=1 reproduces them token-for-token). ``prefill_bucket`` > 1
    right-pads prompts up to a multiple, trading a masked prefill for fewer
    compiled shapes."""

    def __init__(self, cfg: ModelConfig, params, *, runtime: str = "retro",
                 gen_headroom: int = 1024, temperature: float = 0.0,
                 max_context: Optional[int] = None, prefill_bucket: int = 1):
        self.cfg = cfg
        self.params = params
        self.runtime = runtime
        self.gen_headroom = gen_headroom
        self.temperature = temperature
        self.max_context = max_context
        self.prefill_bucket = max(1, prefill_bucket)
        self._prefill_jit: Dict[Any, Any] = {}
        self._decode_jit: Dict[Any, Any] = {}
        self._graft = jax.jit(
            lambda big, small, slot: jax.tree.map(
                lambda b, s: jax.lax.dynamic_update_slice_in_dim(
                    b, s.astype(b.dtype), slot, axis=1), big, small),
            donate_argnums=(0,))
        # sample ON DEVICE: the decode loop only ever moves (B,) token ids to
        # host, never the (B, vocab) logits (at production vocab sizes that
        # transfer would dominate the step).
        self._argmax = jax.jit(
            lambda lg: jnp.argmax(lg, axis=-1).astype(jnp.int32))
        self._categorical = jax.jit(
            lambda key, lg, temp: jax.random.categorical(
                key, lg / temp).astype(jnp.int32))

    # ------------------------------------------------------------- compiled fns
    def _bucket(self, L: int) -> int:
        retro = self.cfg.retro
        if self.cfg.family not in ATTN_FAMILIES:
            return L        # recurrent prefills consume pads: compile exact
        if L < retro.sink + retro.local:
            return L        # too short to mask a ragged tail; compile exact
        b = self.prefill_bucket
        return L if b <= 1 else ((L + b - 1) // b) * b

    def _prefill_fn(self, seq_len: int, max_ctx: int):
        key = (seq_len, max_ctx)
        if key not in self._prefill_jit:
            cfg, rt, gh = self.cfg, self.runtime, self.gen_headroom
            plan = plan_zones(max_ctx, cfg.retro, gh) \
                if cfg.family != "ssm" else None
            ragged = cfg.family in ATTN_FAMILIES

            @jax.jit
            def prefill(params, batch, lengths):
                return M.apply_prefill(params, cfg, batch, runtime=rt,
                                       plan=plan, gen_headroom=gh,
                                       lengths=lengths if ragged else None,
                                       cache_len=max_ctx + gh)

            self._prefill_jit[key] = prefill
        return self._prefill_jit[key]

    def _decode_fns(self, batch_size: int, max_ctx: int):
        key = (batch_size, max_ctx)
        if key not in self._decode_jit:
            cfg, rt, gh = self.cfg, self.runtime, self.gen_headroom
            plan = plan_zones(max_ctx, cfg.retro, gh) \
                if cfg.family != "ssm" else None

            @partial(jax.jit, donate_argnums=(1,))
            def decode(params, state, token, active):
                return M.apply_decode(params, cfg, state, token, runtime=rt,
                                      plan=plan, seq_len=max_ctx,
                                      gen_headroom=gh, active=active)

            @partial(jax.jit, donate_argnums=(0,))
            def flush(state):
                return M.flush_state(cfg, state, runtime=rt)

            self._decode_jit[key] = (decode, flush)
        return self._decode_jit[key]

    # ---------------------------------------------------------------- serving
    def _sample(self, logits, key) -> np.ndarray:
        """Device logits -> host (B,) token ids (blocks until ready)."""
        if self.temperature <= 0:
            drawn = self._argmax(logits)
        else:
            drawn = self._categorical(key, logits,
                                      jnp.float32(self.temperature))
        return np.asarray(drawn).astype(np.int64)

    def serve(self, requests: List[Request], batch_size: int,
              seed: int = 0) -> ServeMetrics:
        """Serve a FIFO queue through ``batch_size`` continuous slots."""
        cfg, rt = self.cfg, self.runtime
        assert requests
        max_ctx = self.max_context or max(
            self._bucket(len(r.prompt)) for r in requests)
        min_len = cfg.retro.sink + 1 \
            if rt == "retro" and cfg.family != "ssm" else 1
        for r in requests:
            if not min_len <= len(r.prompt) <= max_ctx:
                raise ValueError(
                    f"prompt length {len(r.prompt)} outside "
                    f"[{min_len}, {max_ctx}]")
        B = batch_size
        decode, flush = self._decode_fns(B, max_ctx)
        state = M.make_serve_state(cfg, B, max_ctx, runtime=rt,
                                   gen_headroom=self.gen_headroom,
                                   zero_fill=True)
        lbuf = local_buffer_size(cfg.retro)
        use_flush = rt == "retro" and cfg.family != "ssm"

        queue = deque(requests)
        slots: List[Optional[Request]] = [None] * B
        active = np.zeros(B, bool)
        tokens = np.zeros(B, np.int64)
        staged = np.zeros(B, np.int64)      # host mirror of local_len (retro)
        admit_t = np.zeros(B, float)
        metrics = ServeMetrics(n_slots=B)
        key = jax.random.PRNGKey(seed)
        t_start = time.perf_counter()

        def finish(i: int, req: Request):
            req.done = True
            dt = time.perf_counter() - admit_t[i]
            n_decode = len(req.out_tokens) - 1   # first token is prefill's
            req.decode_tps = n_decode / dt if dt > 0 and n_decode > 0 else 0.0
            metrics.request_tps.append(req.decode_tps)
            slots[i] = None
            active[i] = False

        while queue or active.any():
            # ---- admission: fill free slots from the queue -----------------
            for i in range(B):
                if active[i] or not queue:
                    continue
                req = queue.popleft()
                L = len(req.prompt)
                S_b = min(self._bucket(L), max_ctx)
                assert S_b >= L
                toks = np.zeros((1, S_b), np.int32)
                toks[0, :L] = req.prompt
                batch = {"tokens": jnp.asarray(toks)}
                if req.extra:
                    batch.update(req.extra)
                t0 = time.perf_counter()
                prefill = self._prefill_fn(S_b, max_ctx)
                logits, st1 = prefill(self.params, batch,
                                      jnp.asarray([L], jnp.int32))
                state = self._graft(state, st1, jnp.asarray(i, jnp.int32))
                key, sub = jax.random.split(key)
                tok = int(self._sample(logits, sub)[0])  # blocks until ready
                metrics.prefill_s += time.perf_counter() - t0
                req.ttft_s = time.perf_counter() - t_start
                req.out_tokens.append(tok)
                metrics.tokens_out += 1
                metrics.ttft_s.append(req.ttft_s)
                admit_t[i] = time.perf_counter()
                if len(req.out_tokens) >= req.max_new_tokens:
                    finish(i, req)
                    continue
                slots[i] = req
                active[i] = True
                tokens[i] = tok
                staged[i] = min(cfg.retro.local, max(S_b - cfg.retro.sink, 0))
            if not active.any():
                if not queue:
                    break
                continue

            # ---- one decode step over the whole slot batch -----------------
            t0 = time.perf_counter()
            logits, state = decode(self.params, state,
                                   jnp.asarray(tokens, jnp.int32),
                                   jnp.asarray(active))
            key, sub = jax.random.split(key)
            sampled = self._sample(logits, sub)     # blocks until ready
            metrics.decode_s += time.perf_counter() - t0
            metrics.steps += 1
            metrics.occupied_slot_steps += int(active.sum())
            staged[active] += 1
            for i in range(B):
                if not active[i]:
                    continue
                req = slots[i]
                tok = int(sampled[i])
                req.out_tokens.append(tok)
                metrics.tokens_out += 1
                tokens[i] = tok
                if len(req.out_tokens) >= req.max_new_tokens:
                    finish(i, req)

            # ---- per-row masked index update (off the per-step hot path) ---
            if use_flush and (staged >= lbuf).any():
                state = flush(state)
                staged[staged >= lbuf] -= cfg.retro.update_segment
        return metrics

    def run_wave(self, requests: List[Request],
                 extra_batch: Optional[Dict] = None,
                 seed: int = 0) -> ServeMetrics:
        """Back-compat: serve one batch of requests with one slot each."""
        if extra_batch:
            for i, r in enumerate(requests):
                r.extra = {k: v[i:i + 1] for k, v in extra_batch.items()}
        return self.serve(requests, batch_size=len(requests), seed=seed)
