"""Batch-synchronous serving engine.

Processes requests in waves of the configured batch size (the paper's
throughput experiments use fixed batches per context length): prefill builds
the wave index (or dense cache), then jit'd decode steps generate tokens.
Tracks per-wave token throughput and, in retro mode, retrieval statistics.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from functools import partial
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.zones import plan_zones
from repro.models import model as M


@dataclass
class Request:
    prompt: np.ndarray                  # (S,) int32
    max_new_tokens: int
    out_tokens: List[int] = field(default_factory=list)
    done: bool = False


@dataclass
class WaveMetrics:
    prefill_s: float = 0.0
    decode_s: float = 0.0
    tokens_out: int = 0

    @property
    def decode_tps(self) -> float:
        return self.tokens_out / max(self.decode_s, 1e-9)


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, *, runtime: str = "retro",
                 gen_headroom: int = 1024, temperature: float = 0.0):
        self.cfg = cfg
        self.params = params
        self.runtime = runtime
        self.gen_headroom = gen_headroom
        self.temperature = temperature
        self._prefill_jit: Dict[int, Any] = {}
        self._decode_jit: Dict[int, Any] = {}

    def _get_fns(self, seq_len: int):
        if seq_len not in self._prefill_jit:
            cfg, rt, gh = self.cfg, self.runtime, self.gen_headroom
            plan = plan_zones(seq_len, cfg.retro, gh) \
                if cfg.family != "ssm" else None

            @jax.jit
            def prefill(params, batch):
                return M.apply_prefill(params, cfg, batch, runtime=rt,
                                       plan=plan, gen_headroom=gh)

            @partial(jax.jit, donate_argnums=(1,))
            def decode(params, state, token):
                return M.apply_decode(params, cfg, state, token, runtime=rt,
                                      plan=plan, seq_len=seq_len,
                                      gen_headroom=gh)

            @partial(jax.jit, donate_argnums=(0,))
            def flush(state):
                return M.flush_state(cfg, state, runtime=rt)

            self._prefill_jit[seq_len] = prefill
            self._decode_jit[seq_len] = (decode, flush)
        return self._prefill_jit[seq_len], self._decode_jit[seq_len]

    def _sample(self, logits: jax.Array, key) -> jax.Array:
        if self.temperature <= 0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits / self.temperature).astype(jnp.int32)

    def run_wave(self, requests: List[Request], extra_batch: Optional[Dict] = None,
                 seed: int = 0) -> WaveMetrics:
        """Run one batch wave to completion (all prompts same length)."""
        cfg = self.cfg
        S = len(requests[0].prompt)
        assert all(len(r.prompt) == S for r in requests)
        prefill, (decode, flush) = self._get_fns(S)
        metrics = WaveMetrics()
        batch = {"tokens": jnp.asarray(np.stack([r.prompt for r in requests]))}
        if extra_batch:
            batch.update(extra_batch)
        key = jax.random.PRNGKey(seed)

        t0 = time.perf_counter()
        logits, state = jax.block_until_ready(prefill(self.params, batch))
        metrics.prefill_s = time.perf_counter() - t0

        key, sub = jax.random.split(key)
        token = self._sample(logits, sub)
        max_new = max(r.max_new_tokens for r in requests)
        t0 = time.perf_counter()
        appended = 0
        for step in range(max_new):
            for i, r in enumerate(requests):
                if not r.done:
                    r.out_tokens.append(int(token[i]))
                    metrics.tokens_out += 1
                    if len(r.out_tokens) >= r.max_new_tokens:
                        r.done = True
            if all(r.done for r in requests):
                break
            logits, state = decode(self.params, state, token)
            appended += 1
            if self.runtime == "retro" and M.needs_flush(cfg, appended):
                state = flush(state)     # the paper's async 1K-token update
                appended = 0
            key, sub = jax.random.split(key)
            token = self._sample(logits, sub)
        jax.block_until_ready(token)
        metrics.decode_s = time.perf_counter() - t0
        return metrics

    def serve(self, requests: List[Request], batch_size: int) -> List[WaveMetrics]:
        """Process a request queue in fixed-size waves."""
        out = []
        for i in range(0, len(requests), batch_size):
            wave = requests[i:i + batch_size]
            while len(wave) < batch_size:            # pad the last wave
                wave.append(Request(prompt=wave[0].prompt.copy(),
                                    max_new_tokens=wave[0].max_new_tokens))
            out.append(self.run_wave(wave[:batch_size]))
        return out
