"""Step-function builders lowered by the launcher / dry-run.

Shapes are the assignment's contract:
  * train_4k    -> train_step(state, batch)
  * prefill_32k -> prefill_step(params, batch)       (builds the wave index)
  * decode_32k / long_500k -> serve_step(params, state, token)  (1 new token)
"""
from __future__ import annotations

from typing import Callable, Optional

from repro.configs.base import InputShape, ModelConfig
from repro.core.zones import plan_zones
from repro.models import model as M
from repro.training.optimizer import AdamWConfig
from repro.training.train_loop import make_train_step


def make_prefill_step(cfg: ModelConfig, seq_len: int, *, runtime: str = "retro",
                      gen_headroom: int = 4096) -> Callable:
    plan = plan_zones(seq_len, cfg.retro, gen_headroom) \
        if cfg.family != "ssm" else None

    def prefill_step(params, batch):
        return M.apply_prefill(params, cfg, batch, runtime=runtime, plan=plan,
                               gen_headroom=gen_headroom)

    return prefill_step


def make_serve_step(cfg: ModelConfig, seq_len: int, *, runtime: str = "retro",
                    gen_headroom: int = 4096) -> Callable:
    plan = plan_zones(seq_len, cfg.retro, gen_headroom) \
        if cfg.family != "ssm" else None

    def serve_step(params, state, token, active=None):
        """``active``: optional (B,) bool continuous-batching slot mask —
        free slots skip their KV append so per-row counters never drift
        while the scheduler admits/evicts around them."""
        return M.apply_decode(params, cfg, state, token, runtime=runtime,
                              plan=plan, seq_len=seq_len,
                              gen_headroom=gen_headroom, active=active)

    return serve_step


def make_serve_step_split(cfg: ModelConfig, seq_len: int, *,
                          gen_headroom: int = 4096,
                          unroll: bool = False, mesh=None) -> Callable:
    """Hot/cold-split retro decode (transformer families only; §Perf iter 1).

    serve_step(params, cold, hot, token) -> (logits, new_hot)."""
    from repro.models import transformer
    assert cfg.family in ("dense", "moe", "vlm"), cfg.family
    plan = plan_zones(seq_len, cfg.retro, gen_headroom)

    def serve_step(params, cold, hot, token):
        return transformer.decode_step_split(params, cfg, cold, hot, token,
                                             plan=plan, unroll=unroll,
                                             mesh=mesh)

    return serve_step


def make_step(cfg: ModelConfig, shape: InputShape, *, runtime: str = "retro",
              opt_cfg: Optional[AdamWConfig] = None,
              gen_headroom: int = 4096) -> Callable:
    if shape.kind == "train":
        return make_train_step(cfg, opt_cfg or AdamWConfig())
    if shape.kind == "prefill":
        return make_prefill_step(cfg, shape.seq_len, runtime=runtime,
                                 gen_headroom=gen_headroom)
    return make_serve_step(cfg, shape.seq_len, runtime=runtime,
                           gen_headroom=gen_headroom)
