"""Pure-jnp oracle for the fused wave-attention kernel."""
from __future__ import annotations

from repro.core.attention import tripartite_merge_jnp


def wave_attention_ref(q, k, v, valid, est_logit, cs, vs, *, softcap=None):
    """Flat-batch oracle. q: (BH, G, hd); k/v: (BH, T, hd); valid: (BH, T);
    est_logit/cs: (BH, G, E); vs: (BH, E, hd) -> (BH, G, hd) f32."""
    add = lambda a: a[:, None]                     # (BH, ...) -> (BH, 1, ...)
    out = tripartite_merge_jnp(add(q), add(k), add(v), add(valid > 0),
                               add(est_logit), add(cs), add(vs),
                               softcap=softcap)
    return out[:, 0]
