"""Pure-jnp oracles for the fused wave-attention kernels."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.kernels.wave_attention.kernel import NEG


def wave_attention_ref(q, k, v, valid, est_logit, cs, vs, *, softcap=None):
    """Flat-batch oracle. q: (BH, G, hd); k/v: (BH, T, hd); valid: (BH, T);
    est_logit/cs: (BH, G, E); vs: (BH, E, hd) -> (BH, G, hd) f32."""
    from repro.core.attention import tripartite_merge_jnp
    add = lambda a: a[:, None]                     # (BH, ...) -> (BH, 1, ...)
    out = tripartite_merge_jnp(add(q), add(k), add(v), add(valid > 0),
                               add(est_logit), add(cs), add(vs),
                               softcap=softcap)
    return out[:, 0]


def paged_wave_attention_jnp(idx, rowb, live, q, sink_k, sink_v,
                             local_k, local_v, local_pos,
                             k_store, v_store, pos_store,
                             est_logit, cs, vs, *, sink_len: int,
                             softcap=None):
    """Gather-free zone-walk in plain jnp — the interpretable twin of
    ``kernel.paged_wave_attention_pallas`` (same arguments, same fold order:
    sink -> local buffer -> one scan step per retrieved cluster -> estimation
    finalize). This is what "fused" resolves to on CPU: the jax 0.4.x Pallas
    interpreter carries every input ref as mutable while-loop state and
    copies the full cluster stores each step, defeating the kernel's point;
    this path keeps the gather-free dataflow — the ``lax.scan`` body slices
    ONE (cap, hd) block per row per step, so no (BH, r, cap, hd) gather temp
    and no execution-buffer concat ever materializes.

    Like the kernel, ``idx`` is just an address into the (BH, N, cap, ...)
    block store handed in: cluster ids against the monolithic stores (direct
    path) or translated cache slots against the serve engine's device block
    cache + miss staging tail (host-offload path) — this function is the CPU
    data plane of ``ServeEngine(offload=True)``.
    """
    BH, G, hd = q.shape
    scale = 1.0 / math.sqrt(hd)
    f32 = jnp.float32
    q = q.astype(f32)
    lo = rowb[:, 0][:, None].astype(jnp.int32)     # (BH, 1) excl lower bound
    hi = rowb[:, 1][:, None].astype(jnp.int32)     # (BH, 1) incl upper bound

    def fold(carry, k, v, pos, extra_ok=None):
        """Online-softmax accumulate of one (BH, T, hd) tile (identical math
        to the kernel's per-block fold). pos: (BH, T) int32, -1 = empty."""
        m, l, acc = carry                          # (BH,G) (BH,G) (BH,G,hd)
        s = jnp.einsum("bgd,btd->bgt", q, k.astype(f32)) * scale
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        ok = (pos >= 0) & (pos <= hi) & (pos > lo)
        if extra_ok is not None:
            ok = ok & extra_ok
        s = jnp.where(ok[:, None, :], s, NEG)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        m_safe = jnp.maximum(m_new, -1e20)
        corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(ok[:, None, :], p, 0.0)
        l = l * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum("bgt,btd->bgd", p,
                                                 v.astype(f32))
        return m_new, l, acc

    carry = (jnp.full((BH, G), -jnp.inf, f32), jnp.zeros((BH, G), f32),
             jnp.zeros((BH, G, hd), f32))

    sink_pos = jnp.broadcast_to(
        jnp.arange(sink_len, dtype=jnp.int32)[None, :], (BH, sink_len))
    carry = fold(carry, sink_k[:, :sink_len], sink_v[:, :sink_len], sink_pos)
    carry = fold(carry, local_k, local_v, local_pos)

    def cluster_step(carry, xs):
        idx_j, live_j = xs                         # (BH,), (BH,)
        take = lambda a: jnp.take_along_axis(
            a, idx_j.reshape((BH,) + (1,) * (a.ndim - 1)), axis=1)[:, 0]
        return fold(carry, take(k_store), take(v_store), take(pos_store),
                    extra_ok=(live_j > 0)[:, None]), None

    carry, _ = jax.lax.scan(cluster_step, carry, (idx.T, live.T))

    m, l, acc = carry
    m_fin = jnp.maximum(jnp.maximum(m, jnp.max(est_logit, axis=-1)), -1e20)
    corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_fin), 0.0)
    est_live = est_logit > NEG / 2
    w_den = jnp.where(est_live, jnp.exp(est_logit - m_fin[..., None]), 0.0)
    w_num = jnp.where(est_live, jnp.exp(cs - m_fin[..., None]), 0.0)
    den = l * corr + jnp.sum(w_den, axis=-1)
    num = acc * corr[..., None] + jnp.einsum("bge,bed->bgd", w_num, vs)
    return num / jnp.maximum(den, 1e-30)[..., None]
