"""Pallas TPU kernels: fused tripartite wave attention (decode step).

The paper modifies FlashAttention to (a) run over the retrieved KV blocks
(steady zone + retrieval zone) and (b) merge the centroid estimation zone
into the same online softmax (Sec. 4.6). Two TPU adaptations live here:

``wave_attention_pallas`` — the original gathered-buffer kernel:

* grid = (B*Hkv, T_blocks): each step streams one (Tb, hd) K/V tile
  HBM->VMEM; the (G, hd) query tile and (G,) running (m, l) plus the (G, hd)
  accumulator live in VMEM scratch across the T-block loop (classic flash).
* the estimation zone — (G, E) cluster logits + (E, hd) value sums — is folded
  in at the *last* grid step, re-using the same max-stabilized merge; this is
  the "weighted attention" modification of the paper's FlashAttention kernel.
* hd / Tb / E are padded by ops.py to MXU/VPU-friendly multiples (128 lanes).

``paged_wave_attention_pallas`` — the gather-free paged kernel (see
README.md): same online softmax, but the retrieved clusters are read from
``k_store``/``v_store`` IN PLACE via scalar-prefetched cluster ids driving the
BlockSpec index maps (the paged-attention idiom of ``kernels/gather``) — the
caller never materializes a (B, H, r, cap, hd) gather temp nor an
execution-buffer concat.

Validated on CPU with interpret=True against ``ref.tripartite_merge_jnp``.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, valid_ref, est_logit_ref, cs_ref, vs_ref,
            o_ref, m_scr, l_scr, acc_scr, *, softcap, scale, nblocks):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, -jnp.inf)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0]                                    # (G, hd) f32
    k = k_ref[0]                                    # (Tb, hd)
    v = v_ref[0]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    ok = valid_ref[0] > 0                           # (Tb,)
    s = jnp.where(ok[None, :], s, NEG)              # (G, Tb)

    m_prev = m_scr[...]                             # (G, 1) layout -> (G,)
    m_new = jnp.maximum(m_prev[:, 0], jnp.max(s, axis=-1))
    m_safe = jnp.maximum(m_new, -1e20)
    corr = jnp.where(jnp.isfinite(m_prev[:, 0]),
                     jnp.exp(m_prev[:, 0] - m_safe), 0.0)
    p = jnp.exp(s - m_safe[:, None])
    p = jnp.where(ok[None, :], p, 0.0)
    l_scr[...] = (l_scr[...] * corr[:, None]
                  + jnp.sum(p, axis=-1, keepdims=True))
    acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new[:, None]

    @pl.when(j == nblocks - 1)
    def _finalize():
        est_logit = est_logit_ref[0]                # (G, E)
        cs = cs_ref[0]                              # (G, E)
        vs = vs_ref[0]                              # (E, hd)
        m_prev = m_scr[...][:, 0]
        m_fin = jnp.maximum(jnp.maximum(m_prev, jnp.max(est_logit, axis=-1)),
                            -1e20)
        corr = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - m_fin), 0.0)
        live = est_logit > NEG / 2
        w_den = jnp.where(live, jnp.exp(est_logit - m_fin[:, None]), 0.0)
        w_num = jnp.where(live, jnp.exp(cs - m_fin[:, None]), 0.0)
        den = l_scr[...][:, 0] * corr + jnp.sum(w_den, axis=-1)
        num = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
            w_num, vs, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        o_ref[0] = num / jnp.maximum(den, 1e-30)[:, None]


def wave_attention_pallas(q, k, v, valid, est_logit, cs, vs, *,
                          softcap=None, block_t: int = 512,
                          interpret: bool = False):
    """q: (BH, G, hd) f32; k/v: (BH, T, hd) f32; valid: (BH, T) int32;
    est_logit/cs: (BH, G, E) f32; vs: (BH, E, hd) f32 -> (BH, G, hd) f32.
    T must be a multiple of block_t (ops.py pads)."""
    BH, G, hd = q.shape
    T = k.shape[1]
    E = vs.shape[1]
    assert T % block_t == 0, (T, block_t)
    nblocks = T // block_t
    scale = 1.0 / math.sqrt(hd)

    kern = functools.partial(_kernel, softcap=softcap, scale=scale,
                             nblocks=nblocks)
    grid = (BH, nblocks)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, G, hd), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1, block_t, hd), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, block_t, hd), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, block_t), lambda b, j: (b, j)),
            pl.BlockSpec((1, G, E), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1, G, E), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1, E, hd), lambda b, j: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, G, hd), lambda b, j: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, G, hd), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, valid, est_logit, cs, vs)


# ---------------------------------------------------------------------------
# Gather-free paged kernel: steady zone + in-place retrieved clusters.
#
# Two cluster-walk flavors share the fold/finalize math:
#   * BlockSpec walk (``double_buffer=False``): one grid step per retrieved
#     cluster; the scalar-prefetched ids drive the store BlockSpec index maps
#     (the automatic Pallas pipeline moves the blocks).
#   * double-buffered DMA walk (``double_buffer=True``, default): the stores
#     stay in ANY/HBM and one final grid step walks all r clusters with
#     explicit ``make_async_copy`` into a 2-slot VMEM scratch — the DMA for
#     cluster j+1 is started BEFORE folding cluster j, so the j+1 transfer
#     overlaps the j compute (the paper's async data movement, Sec. 4.3/4.6).
# ---------------------------------------------------------------------------


def _paged_kernel(idx_ref, rowb_ref, live_ref,
                  q_ref, sk_ref, sv_ref, lk_ref, lv_ref, lp_ref,
                  kst_ref, vst_ref, pst_ref, el_ref, cs_ref, vs_ref,
                  o_ref, m_scr, l_scr, acc_scr, *,
                  softcap, scale, sink, n_local_blocks, nblocks):
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, -jnp.inf)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)                # (G, hd)
    lo = rowb_ref[b, 0]                             # window lower bound (excl)
    hi = rowb_ref[b, 1]                             # q_pos (incl)
    fold = _make_fold(q, lo, hi, m_scr, l_scr, acc_scr, softcap=softcap,
                      scale=scale)

    @pl.when(j == 0)
    def _fold_sink():
        # sink positions are implicit: slot t holds token t; ops.py pads the
        # sink axis, so slots >= the true sink width are statically dead
        pos = jax.lax.broadcasted_iota(jnp.int32, (1, sk_ref.shape[1]), 1)
        fold(sk_ref[0].astype(jnp.float32), sv_ref[0].astype(jnp.float32),
             pos, extra_ok=pos < sink)

    @pl.when((j >= 1) & (j < 1 + n_local_blocks))
    def _fold_local():
        fold(lk_ref[0].astype(jnp.float32), lv_ref[0].astype(jnp.float32),
             lp_ref[...])

    @pl.when(j >= 1 + n_local_blocks)
    def _fold_cluster():
        jc = j - (1 + n_local_blocks)
        fold(kst_ref[0, 0].astype(jnp.float32),
             vst_ref[0, 0].astype(jnp.float32),
             pst_ref[0], extra_ok=live_ref[b, jc] > 0)

    @pl.when(j == nblocks - 1)
    def _finalize():
        _est_finalize(el_ref, cs_ref, vs_ref, o_ref, m_scr, l_scr, acc_scr)


def _make_fold(q, lo, hi, m_scr, l_scr, acc_scr, *, softcap, scale):
    """Online-softmax accumulate of one (T, hd) tile against the (G,) running
    (m, l) + (G, hd) accumulator scratch; pos: (1, T) int32 token positions
    (-1 = empty slot). Shared by both cluster-walk flavors."""
    def fold(k, v, pos, extra_ok=True):
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        ok = (pos >= 0) & (pos <= hi) & (pos > lo) & extra_ok   # (1, T)
        s = jnp.where(ok, s, NEG)                   # (G, T)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev[:, 0], jnp.max(s, axis=-1))
        m_safe = jnp.maximum(m_new, -1e20)
        corr = jnp.where(jnp.isfinite(m_prev[:, 0]),
                         jnp.exp(m_prev[:, 0] - m_safe), 0.0)
        p = jnp.exp(s - m_safe[:, None])
        p = jnp.where(ok, p, 0.0)
        l_scr[...] = (l_scr[...] * corr[:, None]
                      + jnp.sum(p, axis=-1, keepdims=True))
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_scr[...] = m_new[:, None]
    return fold


def _est_finalize(el_ref, cs_ref, vs_ref, o_ref, m_scr, l_scr, acc_scr):
    """Merge the estimation zone into the accumulated exact softmax and write
    the output (the paper's 'weighted attention' finalize)."""
    est_logit = el_ref[0]                       # (G, E)
    cs = cs_ref[0]                              # (G, E)
    vs = vs_ref[0]                              # (E, hd)
    m_prev = m_scr[...][:, 0]
    m_fin = jnp.maximum(jnp.maximum(m_prev, jnp.max(est_logit, axis=-1)),
                        -1e20)
    corr = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - m_fin), 0.0)
    live = est_logit > NEG / 2
    w_den = jnp.where(live, jnp.exp(est_logit - m_fin[:, None]), 0.0)
    w_num = jnp.where(live, jnp.exp(cs - m_fin[:, None]), 0.0)
    den = l_scr[...][:, 0] * corr + jnp.sum(w_den, axis=-1)
    num = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
        w_num, vs, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    o_ref[0] = num / jnp.maximum(den, 1e-30)[:, None]


def _paged_db_kernel(idx_ref, rowb_ref, live_ref,
                     q_ref, sk_ref, sv_ref, lk_ref, lv_ref, lp_ref,
                     kst_ref, vst_ref, pst_ref, el_ref, cs_ref, vs_ref,
                     o_ref, m_scr, l_scr, acc_scr,
                     kdb_scr, vdb_scr, pdb_scr, ksem, vsem, psem, *,
                     softcap, scale, sink, n_local_blocks, nblocks, r):
    """Double-buffered flavor: the stores stay in ANY/HBM; the LAST grid step
    walks all r retrieved clusters, DMA'ing cluster j+1's (cap, hd) blocks
    into the other half of a 2-slot VMEM scratch while folding cluster j."""
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, -jnp.inf)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)                # (G, hd)
    lo = rowb_ref[b, 0]                             # window lower bound (excl)
    hi = rowb_ref[b, 1]                             # q_pos (incl)
    fold = _make_fold(q, lo, hi, m_scr, l_scr, acc_scr, softcap=softcap,
                      scale=scale)

    @pl.when(j == 0)
    def _fold_sink():
        pos = jax.lax.broadcasted_iota(jnp.int32, (1, sk_ref.shape[1]), 1)
        fold(sk_ref[0].astype(jnp.float32), sv_ref[0].astype(jnp.float32),
             pos, extra_ok=pos < sink)

    @pl.when((j >= 1) & (j < 1 + n_local_blocks))
    def _fold_local():
        fold(lk_ref[0].astype(jnp.float32), lv_ref[0].astype(jnp.float32),
             lp_ref[...])

    @pl.when(j == nblocks - 1)
    def _fold_clusters_finalize():
        def dmas(slot, jc):
            cid = idx_ref[b, jc]
            return (
                pltpu.make_async_copy(kst_ref.at[b, cid], kdb_scr.at[slot],
                                      ksem.at[slot]),
                pltpu.make_async_copy(vst_ref.at[b, cid], vdb_scr.at[slot],
                                      vsem.at[slot]),
                pltpu.make_async_copy(pst_ref.at[b, pl.ds(cid, 1)],
                                      pdb_scr.at[slot], psem.at[slot]),
            )

        for c in dmas(0, 0):                        # warm up: cluster 0
            c.start()

        def body(jc, carry):
            cur = jax.lax.rem(jc, 2)
            nxt = jax.lax.rem(jc + 1, 2)

            @pl.when(jc + 1 < r)
            def _prefetch_next():                   # overlap j+1 DMA w/ fold j
                for c in dmas(nxt, jc + 1):
                    c.start()

            for c in dmas(cur, jc):
                c.wait()
            fold(kdb_scr[cur].astype(jnp.float32),
                 vdb_scr[cur].astype(jnp.float32),
                 pdb_scr[cur], extra_ok=live_ref[b, jc] > 0)
            return carry

        jax.lax.fori_loop(0, r, body, 0)
        _est_finalize(el_ref, cs_ref, vs_ref, o_ref, m_scr, l_scr, acc_scr)


def paged_wave_attention_pallas(idx, rowb, live, q, sink_k, sink_v,
                                local_k, local_v, local_pos,
                                k_store, v_store, pos_store,
                                est_logit, cs, vs, *,
                                sink_len: int, softcap=None,
                                block_l: int = 512,
                                double_buffer: bool = True,
                                interpret: bool = False):
    """Gather-free fused decode attention over the raw wave-index zones.

    idx/live: (BH, r) int32 retrieved cluster ids + validity (scalar
    prefetch); rowb: (BH, 2) int32 [window_lo (exclusive), q_pos (inclusive)];
    q: (BH, G, hd) f32; sink_k/v: (BH, Ss, hd) — slot t holds token t, slots
    >= ``sink_len`` are alignment padding; local_k/v: (BH, Lp, hd) with
    local_pos (BH, Lp) int32 (-1 = empty, Lp a multiple of block_l);
    k/v/pos_store: (BH, M, cap, hd) / (BH, M, cap) — read IN PLACE, one
    (cap, hd) block per retrieved cluster; est_logit/cs: (BH, G, E) f32 f32;
    vs: (BH, E, hd) f32. Returns (BH, G, hd) f32.

    ``idx`` may address any block store with a (BH, N, cap, ...) layout —
    the monolithic cluster stores (direct path, ids = cluster ids) or the
    serve engine's device block cache + miss staging buffer (host-offload
    path, ids = cache slots); the kernel is agnostic.

    ``double_buffer=True`` (default): grid (BH, 1 + Lp/block_l + 1) — the
    final step walks all r clusters with explicit double-buffered DMA
    (cluster j+1's blocks stream HBM->VMEM while cluster j folds).
    ``double_buffer=False``: grid (BH, 1 + Lp/block_l + r) — one step per
    cluster, the prefetched ``idx`` driving the store BlockSpec index maps
    (paged-attention idiom; the automatic pipeline moves the blocks).
    """
    BH, G, hd = q.shape
    M, cap = k_store.shape[1], k_store.shape[2]
    r = idx.shape[1]
    Ss = sink_k.shape[1]
    Lp = local_k.shape[1]
    E = vs.shape[1]
    assert r >= 1 and Lp % block_l == 0, (r, Lp, block_l)
    nlb = Lp // block_l
    nblocks = (1 + nlb + 1) if double_buffer else (1 + nlb + r)
    scale = 1.0 / math.sqrt(hd)

    lmap = lambda b, j, *_: (b, jnp.clip(j - 1, 0, nlb - 1), 0)
    lpmap = lambda b, j, *_: (b, jnp.clip(j - 1, 0, nlb - 1))
    cmap = lambda b, j, idx_ref, *_: \
        (b, idx_ref[b, jnp.clip(j - 1 - nlb, 0, r - 1)], 0, 0)
    cpmap = lambda b, j, idx_ref, *_: \
        (b, idx_ref[b, jnp.clip(j - 1 - nlb, 0, r - 1)], 0)
    park = lambda b, j, *_: (b, 0, 0)

    scratch = [
        pltpu.VMEM((G, 1), jnp.float32),
        pltpu.VMEM((G, 1), jnp.float32),
        pltpu.VMEM((G, hd), jnp.float32),
    ]
    if double_buffer:
        kern = functools.partial(_paged_db_kernel, softcap=softcap,
                                 scale=scale, sink=sink_len,
                                 n_local_blocks=nlb, nblocks=nblocks, r=r)
        store_specs = [
            pl.BlockSpec(memory_space=pltpu.ANY),               # k_store
            pl.BlockSpec(memory_space=pltpu.ANY),               # v_store
            pl.BlockSpec(memory_space=pltpu.ANY),               # pos_store
        ]
        scratch = scratch + [
            pltpu.VMEM((2, cap, hd), k_store.dtype),            # k double buf
            pltpu.VMEM((2, cap, hd), v_store.dtype),            # v double buf
            pltpu.VMEM((2, 1, cap), pos_store.dtype),           # pos double buf
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.SemaphoreType.DMA((2,)),
        ]
    else:
        kern = functools.partial(_paged_kernel, softcap=softcap, scale=scale,
                                 sink=sink_len, n_local_blocks=nlb,
                                 nblocks=nblocks)
        store_specs = [
            pl.BlockSpec((1, 1, cap, hd), cmap),                # k_store
            pl.BlockSpec((1, 1, cap, hd), cmap),                # v_store
            pl.BlockSpec((1, 1, cap), cpmap),                   # pos_store
        ]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(BH, nblocks),
        in_specs=[
            pl.BlockSpec((1, G, hd), park),                     # q
            pl.BlockSpec((1, Ss, hd), park),                    # sink_k
            pl.BlockSpec((1, Ss, hd), park),                    # sink_v
            pl.BlockSpec((1, block_l, hd), lmap),               # local_k
            pl.BlockSpec((1, block_l, hd), lmap),               # local_v
            pl.BlockSpec((1, block_l), lpmap),                  # local_pos
        ] + store_specs + [
            pl.BlockSpec((1, G, E), park),                      # est_logit
            pl.BlockSpec((1, G, E), park),                      # cs
            pl.BlockSpec((1, E, hd), park),                     # vs
        ],
        out_specs=pl.BlockSpec((1, G, hd), park),
        scratch_shapes=scratch,
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((BH, G, hd), jnp.float32),
        interpret=interpret,
    )(idx, rowb, live, q, sink_k, sink_v, local_k, local_v, local_pos,
      k_store, v_store, pos_store, est_logit, cs, vs)
