"""Pallas TPU kernel: fused tripartite wave attention (decode step).

The paper modifies FlashAttention to (a) run over the gathered execution
buffer (steady zone + retrieved cluster blocks) and (b) merge the centroid
estimation zone into the same online softmax (Sec. 4.6). TPU adaptation:

* grid = (B*Hkv, T_blocks): each step streams one (Tb, hd) K/V tile
  HBM->VMEM; the (G, hd) query tile and (G,) running (m, l) plus the (G, hd)
  accumulator live in VMEM scratch across the T-block loop (classic flash).
* the estimation zone — (G, E) cluster logits + (E, hd) value sums — is folded
  in at the *last* grid step, re-using the same max-stabilized merge; this is
  the "weighted attention" modification of the paper's FlashAttention kernel.
* hd / Tb / E are padded by ops.py to MXU/VPU-friendly multiples (128 lanes).

Validated on CPU with interpret=True against ``ref.tripartite_merge_jnp``.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, valid_ref, est_logit_ref, cs_ref, vs_ref,
            o_ref, m_scr, l_scr, acc_scr, *, softcap, scale, nblocks):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, -jnp.inf)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0]                                    # (G, hd) f32
    k = k_ref[0]                                    # (Tb, hd)
    v = v_ref[0]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    ok = valid_ref[0] > 0                           # (Tb,)
    s = jnp.where(ok[None, :], s, NEG)              # (G, Tb)

    m_prev = m_scr[...]                             # (G, 1) layout -> (G,)
    m_new = jnp.maximum(m_prev[:, 0], jnp.max(s, axis=-1))
    m_safe = jnp.maximum(m_new, -1e20)
    corr = jnp.where(jnp.isfinite(m_prev[:, 0]),
                     jnp.exp(m_prev[:, 0] - m_safe), 0.0)
    p = jnp.exp(s - m_safe[:, None])
    p = jnp.where(ok[None, :], p, 0.0)
    l_scr[...] = (l_scr[...] * corr[:, None]
                  + jnp.sum(p, axis=-1, keepdims=True))
    acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new[:, None]

    @pl.when(j == nblocks - 1)
    def _finalize():
        est_logit = est_logit_ref[0]                # (G, E)
        cs = cs_ref[0]                              # (G, E)
        vs = vs_ref[0]                              # (E, hd)
        m_prev = m_scr[...][:, 0]
        m_fin = jnp.maximum(jnp.maximum(m_prev, jnp.max(est_logit, axis=-1)),
                            -1e20)
        corr = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - m_fin), 0.0)
        live = est_logit > NEG / 2
        w_den = jnp.where(live, jnp.exp(est_logit - m_fin[:, None]), 0.0)
        w_num = jnp.where(live, jnp.exp(cs - m_fin[:, None]), 0.0)
        den = l_scr[...][:, 0] * corr + jnp.sum(w_den, axis=-1)
        num = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
            w_num, vs, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        o_ref[0] = num / jnp.maximum(den, 1e-30)[:, None]


def wave_attention_pallas(q, k, v, valid, est_logit, cs, vs, *,
                          softcap=None, block_t: int = 512,
                          interpret: bool = False):
    """q: (BH, G, hd) f32; k/v: (BH, T, hd) f32; valid: (BH, T) int32;
    est_logit/cs: (BH, G, E) f32; vs: (BH, E, hd) f32 -> (BH, G, hd) f32.
    T must be a multiple of block_t (ops.py pads)."""
    BH, G, hd = q.shape
    T = k.shape[1]
    E = vs.shape[1]
    assert T % block_t == 0, (T, block_t)
    nblocks = T // block_t
    scale = 1.0 / math.sqrt(hd)

    kern = functools.partial(_kernel, softcap=softcap, scale=scale,
                             nblocks=nblocks)
    grid = (BH, nblocks)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, G, hd), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1, block_t, hd), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, block_t, hd), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, block_t), lambda b, j: (b, j)),
            pl.BlockSpec((1, G, E), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1, G, E), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1, E, hd), lambda b, j: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, G, hd), lambda b, j: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, G, hd), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, valid, est_logit, cs, vs)
