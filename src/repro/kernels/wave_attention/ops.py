"""Jit'd wrapper around the fused wave-attention Pallas kernel.

Handles layout: flattens (B, Hkv) -> BH, pads T to the kernel's block size
and E/hd to VPU-friendly multiples, then restores shapes. Padded exec-buffer
slots are masked invalid; padded estimation slots carry NEG logits.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.wave_attention.kernel import NEG, wave_attention_pallas


def on_cpu() -> bool:
    return jax.devices()[0].platform == "cpu"


def _pad_to(x, axis, mult):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x, size
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), size


@functools.partial(jax.jit, static_argnames=("softcap", "block_t", "interpret"))
def wave_attention_merge(qg, k_exec, v_exec, valid, est_logit, cs_e, vs_e, *,
                         softcap=None, block_t: int = 512,
                         interpret: bool = False):
    """Same contract as ``core.attention.tripartite_merge_jnp``:
    qg (B,H,G,hd), k/v (B,H,T,hd), valid (B,H,T) bool,
    est_logit/cs_e (B,H,G,E), vs_e (B,H,E,hd) -> (B,H,G,hd) f32."""
    B, H, G, hd = qg.shape
    T = k_exec.shape[2]
    E = vs_e.shape[2]
    f32 = jnp.float32

    def flat(a):
        return a.reshape((B * H,) + a.shape[2:])

    q = flat(qg).astype(f32)
    k = flat(k_exec).astype(f32)
    v = flat(v_exec).astype(f32)
    ok = flat(valid).astype(jnp.int32)
    el = flat(est_logit).astype(f32)
    cs = flat(cs_e).astype(f32)
    vs = flat(vs_e).astype(f32)

    bt = min(block_t, max(128, T))
    k, _ = _pad_to(k, 1, bt)
    v, _ = _pad_to(v, 1, bt)
    ok, _ = _pad_to(ok, 1, bt)                      # pads are 0 => invalid
    el = jnp.pad(el, ((0, 0), (0, 0), (0, (-E) % 128)), constant_values=NEG)
    cs = jnp.pad(cs, ((0, 0), (0, 0), (0, (-E) % 128)), constant_values=NEG)
    vs, _ = _pad_to(vs, 1, 128)

    out = wave_attention_pallas(q, k, v, ok, el, cs, vs, softcap=softcap,
                                block_t=bt, interpret=interpret)
    return out.reshape(B, H, G, hd)
