"""Jit'd wrappers around the fused wave-attention Pallas kernels.

Handles layout: flattens (B, Hkv) -> BH, pads T to the kernel's block size
and E/hd to VPU-friendly multiples, then restores shapes. Padded exec-buffer
slots are masked invalid; padded estimation slots carry NEG logits.

``paged_wave_attention`` is the gather-free variant (see README.md): it takes
the raw wave-index zones — sink, local buffer, cluster stores + retrieved
ids — and never materializes a gather temp or execution-buffer concat; only
the tiny steady zone and estimation tensors are padded/copied for alignment.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.wave_attention.kernel import (NEG,
                                                 paged_wave_attention_pallas,
                                                 wave_attention_pallas)
from repro.kernels.wave_attention.ref import paged_wave_attention_jnp


def on_cpu() -> bool:
    return jax.devices()[0].platform == "cpu"


def _pad_to(x, axis, mult):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x, size
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), size


@functools.partial(jax.jit, static_argnames=("softcap", "block_t", "interpret"))
def wave_attention_merge(qg, k_exec, v_exec, valid, est_logit, cs_e, vs_e, *,
                         softcap=None, block_t: int = 512,
                         interpret: bool = False):
    """Same contract as ``core.attention.tripartite_merge_jnp``:
    qg (B,H,G,hd), k/v (B,H,T,hd), valid (B,H,T) bool,
    est_logit/cs_e (B,H,G,E), vs_e (B,H,E,hd) -> (B,H,G,hd) f32."""
    B, H, G, hd = qg.shape
    T = k_exec.shape[2]
    E = vs_e.shape[2]
    f32 = jnp.float32

    def flat(a):
        return a.reshape((B * H,) + a.shape[2:])

    q = flat(qg).astype(f32)
    k = flat(k_exec).astype(f32)
    v = flat(v_exec).astype(f32)
    ok = flat(valid).astype(jnp.int32)
    el = flat(est_logit).astype(f32)
    cs = flat(cs_e).astype(f32)
    vs = flat(vs_e).astype(f32)

    bt = min(block_t, max(128, T))
    k, _ = _pad_to(k, 1, bt)
    v, _ = _pad_to(v, 1, bt)
    ok, _ = _pad_to(ok, 1, bt)                      # pads are 0 => invalid
    el = jnp.pad(el, ((0, 0), (0, 0), (0, (-E) % 128)), constant_values=NEG)
    cs = jnp.pad(cs, ((0, 0), (0, 0), (0, (-E) % 128)), constant_values=NEG)
    vs, _ = _pad_to(vs, 1, 128)

    out = wave_attention_pallas(q, k, v, ok, el, cs, vs, softcap=softcap,
                                block_t=bt, interpret=interpret)
    return out.reshape(B, H, G, hd)


@functools.partial(jax.jit, static_argnames=("softcap", "block_l",
                                             "interpret", "emulate",
                                             "double_buffer"))
def paged_wave_attention(qg, sink_k, sink_v, local_k, local_v, local_pos,
                         k_store, v_store, pos_store, idx_r, live, rowb,
                         est_logit, cs_e, vs_e, *, softcap=None,
                         block_l: int = 512, interpret: bool = False,
                         emulate: bool = None, double_buffer: bool = True):
    """Gather-free fused decode merge over the raw wave-index zones.

    qg: (B, H, G, hd); sink_k/v: (B, H, S, hd); local_k/v: (B, H, Lb, hd)
    with local_pos (B, H, Lb) int32 (-1 = empty slot); k/v_store:
    (B, H, M, cap, hd) with pos_store (B, H, M, cap) — passed through in
    their storage dtype and read in place by the kernel; idx_r/live:
    (B, H, r) int32 retrieved ids + validity; rowb: (B, H, 2) int32
    [window_lo (exclusive), q_pos (inclusive)]; est_logit/cs_e: (B, H, G, E)
    f32; vs_e: (B, H, E, hd) f32. Returns (B, H, G, hd) f32 with semantics
    identical to ``core.attention.tripartite_merge_jnp`` on the gathered
    execution buffer.

    The stores may be the monolithic cluster stores (``idx_r`` = cluster
    ids) or the serve engine's device block cache + miss staging buffer
    (``idx_r`` = cache slots, host-offload configuration) — the kernel only
    sees an id-addressed block store.

    ``emulate`` (default: follows ``interpret``) swaps the Pallas kernel for
    ``ref.paged_wave_attention_jnp`` — the same zone-walk in plain jnp. The
    jax 0.4.x Pallas *interpreter* carries all input refs as mutable loop
    state (full-store copies every grid step), so the CPU serving path uses
    the emulation; interpret=True + emulate=False runs the actual kernel
    through the interpreter (parity tests). ``double_buffer`` selects the
    kernel's cluster walk: explicit double-buffered DMA (default — cluster
    j+1 streams while j folds) vs the one-grid-step-per-cluster BlockSpec
    walk.
    """
    B, H, G, hd = qg.shape
    sink = sink_k.shape[2]
    Lb = local_k.shape[2]
    E = vs_e.shape[2]
    f32 = jnp.float32
    if emulate is None:
        emulate = interpret

    def flat(a):
        return a.reshape((B * H,) + a.shape[2:])

    if emulate:
        out = paged_wave_attention_jnp(
            flat(idx_r).astype(jnp.int32), flat(rowb).astype(jnp.int32),
            flat(live).astype(jnp.int32), flat(qg).astype(f32),
            flat(sink_k), flat(sink_v), flat(local_k), flat(local_v),
            flat(local_pos).astype(jnp.int32), flat(k_store), flat(v_store),
            flat(pos_store).astype(jnp.int32), flat(est_logit).astype(f32),
            flat(cs_e).astype(f32), flat(vs_e).astype(f32), sink_len=sink,
            softcap=softcap)
        return out.reshape(B, H, G, hd)

    # Alignment pads touch only the O(steady)-sized zones and the meta-index
    # estimation tensors — never the cluster stores, which flow through
    # unconverted (an outside astype would copy the ENTIRE store; the kernel
    # casts per block in VMEM).
    sk, _ = _pad_to(flat(sink_k), 1, 16)
    sv, _ = _pad_to(flat(sink_v), 1, 16)
    bl = min(block_l, max(128, Lb))
    lk, _ = _pad_to(flat(local_k), 1, bl)
    lv, _ = _pad_to(flat(local_v), 1, bl)
    lp = flat(local_pos).astype(jnp.int32)
    lp = jnp.pad(lp, ((0, 0), (0, lk.shape[1] - Lb)), constant_values=-1)
    el = flat(est_logit).astype(f32)
    cs = flat(cs_e).astype(f32)
    vs = flat(vs_e).astype(f32)
    el = jnp.pad(el, ((0, 0), (0, 0), (0, (-E) % 128)), constant_values=NEG)
    cs = jnp.pad(cs, ((0, 0), (0, 0), (0, (-E) % 128)), constant_values=NEG)
    vs, _ = _pad_to(vs, 1, 128)

    out = paged_wave_attention_pallas(
        flat(idx_r).astype(jnp.int32), flat(rowb).astype(jnp.int32),
        flat(live).astype(jnp.int32), flat(qg).astype(f32), sk, sv, lk, lv,
        lp, flat(k_store), flat(v_store), flat(pos_store).astype(jnp.int32),
        el, cs, vs, sink_len=sink, softcap=softcap, block_l=bl,
        double_buffer=double_buffer, interpret=interpret)
    return out.reshape(B, H, G, hd)
