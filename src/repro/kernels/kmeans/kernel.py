"""Pallas TPU kernel: segmented spherical k-means iteration (paper Sec. 4.6).

The paper implements segmented clustering as a Triton kernel parallel over
(head, segment). TPU adaptation: grid = (S,) flattened (batch*head*segment);
per step one segment's keys (n, d) and centroids (k, d) are VMEM-resident,
the (n, k) similarity runs on the MXU, and the centroid update is a one-hot
matmul (again MXU) — no scatter needed. Assignment, new centroid sums and
counts are produced in one pass; the iteration loop lives in ops.py.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, cent_ref, sums_ref, counts_ref, assign_ref):
    x = x_ref[0]                                           # (n, d) f32
    c = cent_ref[0]                                        # (k, d) f32
    cn = c * jax.lax.rsqrt(jnp.maximum(
        jnp.sum(c * c, axis=-1, keepdims=True), 1e-16))    # spherical
    sim = jax.lax.dot_general(x, cn, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)  # (n, k)
    assign = jnp.argmax(sim, axis=-1).astype(jnp.int32)    # (n,)
    k = c.shape[0]
    onehot = (assign[:, None] == jax.lax.broadcasted_iota(
        jnp.int32, (x.shape[0], k), 1)).astype(jnp.float32)
    sums_ref[0] = jax.lax.dot_general(
        onehot, x, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                # (k, d)
    counts_ref[0] = jnp.sum(onehot, axis=0)                # (k,)
    assign_ref[0] = assign


def kmeans_step_pallas(x, cent, *, interpret: bool = False):
    """One assignment+update step over stacked segments.

    x: (S, n, d) f32 (pre-centered keys); cent: (S, k, d) f32.
    Returns (sums (S,k,d), counts (S,k), assign (S,n)).
    """
    S, n, d = x.shape
    k = cent.shape[1]
    return pl.pallas_call(
        _kernel,
        grid=(S,),
        in_specs=[
            pl.BlockSpec((1, n, d), lambda s: (s, 0, 0)),
            pl.BlockSpec((1, k, d), lambda s: (s, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, k, d), lambda s: (s, 0, 0)),
            pl.BlockSpec((1, k), lambda s: (s, 0)),
            pl.BlockSpec((1, n), lambda s: (s, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((S, k, d), jnp.float32),
            jax.ShapeDtypeStruct((S, k), jnp.float32),
            jax.ShapeDtypeStruct((S, n), jnp.int32),
        ],
        interpret=interpret,
    )(x, cent)
