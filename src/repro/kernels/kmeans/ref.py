"""Pure-jnp oracle for the segmented k-means step kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def kmeans_step_ref(x, cent):
    """x: (S, n, d); cent: (S, k, d) -> (sums, counts, assign)."""
    cn = cent * jax.lax.rsqrt(
        jnp.maximum(jnp.sum(cent * cent, axis=-1, keepdims=True), 1e-16))
    sim = jnp.einsum("snd,skd->snk", x, cn)
    assign = jnp.argmax(sim, axis=-1).astype(jnp.int32)
    k = cent.shape[1]
    onehot = jax.nn.one_hot(assign, k, dtype=jnp.float32)
    sums = jnp.einsum("snk,snd->skd", onehot, x)
    counts = jnp.sum(onehot, axis=1)
    return sums, counts, assign


def kmeans_ref(x, cent0, iters: int):
    """Full loop oracle: returns (final centroids, assign)."""
    cent = cent0
    for _ in range(iters):
        sums, counts, _ = kmeans_step_ref(x, cent)
        cent = jnp.where(counts[..., None] > 0,
                         sums / jnp.maximum(counts[..., None], 1.0), cent)
    _, _, assign = kmeans_step_ref(x, cent)
    return cent, assign
