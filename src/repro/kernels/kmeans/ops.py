"""Jit'd wrapper: full segmented spherical k-means using the Pallas step."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.kmeans.kernel import kmeans_step_pallas


def on_cpu() -> bool:
    return jax.devices()[0].platform == "cpu"


@functools.partial(jax.jit, static_argnames=("iters", "interpret"))
def segmented_kmeans_op(x, cent0, *, iters: int, interpret: bool = False):
    """x: (S, n, d) f32; cent0: (S, k, d) f32. Returns (centroids, assign)."""

    def body(cent, _):
        sums, counts, _ = kmeans_step_pallas(x, cent, interpret=interpret)
        cent = jnp.where(counts[..., None] > 0,
                         sums / jnp.maximum(counts[..., None], 1.0), cent)
        return cent, None

    cent, _ = jax.lax.scan(body, cent0, None, length=iters)
    _, _, assign = kmeans_step_pallas(x, cent, interpret=interpret)
    return cent, assign
