"""Pure-jnp oracle for the block-gather kernel."""
from __future__ import annotations

import jax.numpy as jnp


def block_gather_ref(idx, k_store, v_store):
    """idx: (BH, r); stores: (BH, M, cap, hd) -> (BH, r, cap, hd) pair."""
    take = lambda s: jnp.take_along_axis(
        s, idx[:, :, None, None], axis=1)
    return take(k_store), take(v_store)
