"""Jit'd wrapper for the execution-buffer gather kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.gather.kernel import block_gather_pallas


def on_cpu() -> bool:
    return jax.devices()[0].platform == "cpu"


@functools.partial(jax.jit, static_argnames=("interpret",))
def block_gather_op(idx, k_store, v_store, *, interpret: bool = False):
    """idx: (B, H, r); stores: (B, H, M, cap, hd) -> (B, H, r, cap, hd)."""
    B, H, r = idx.shape
    _, _, M, cap, hd = k_store.shape
    ko, vo = block_gather_pallas(
        idx.reshape(B * H, r).astype("int32"),
        k_store.reshape(B * H, M, cap, hd),
        v_store.reshape(B * H, M, cap, hd),
        interpret=interpret)
    return (ko.reshape(B, H, r, cap, hd), vo.reshape(B, H, r, cap, hd))
