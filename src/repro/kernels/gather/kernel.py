"""Pallas TPU kernel: execution-buffer assembly (paper Sec. 4.6 copy kernels).

The paper implements ~1000 LoC of CUDA to copy exactly the retrieved KV blocks
into a contiguous execution buffer. TPU adaptation: a scalar-prefetch gather —
the top-r cluster ids are prefetched into SMEM and drive the BlockSpec
index_map, so each grid step DMAs one (cap, hd) cluster block HBM->VMEM and
writes it to the contiguous output. This is the paged-attention gather idiom;
"skipping fragmented regions" falls out of block indexing for free.
"""
from __future__ import annotations

import jax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _copy_kernel(idx_ref, k_src, v_src, k_dst, v_dst):
    k_dst[...] = k_src[...]
    v_dst[...] = v_src[...]


def block_gather_pallas(idx, k_store, v_store, *, interpret: bool = False):
    """Gather cluster blocks into a contiguous execution buffer.

    idx: (BH, r) int32 cluster ids; k_store/v_store: (BH, M, cap, hd).
    Returns (k_out, v_out): (BH, r, cap, hd).
    """
    BH, M, cap, hd = k_store.shape
    r = idx.shape[1]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(BH, r),
        in_specs=[
            pl.BlockSpec((1, 1, cap, hd),
                         lambda b, i, idx_ref: (b, idx_ref[b, i], 0, 0)),
            pl.BlockSpec((1, 1, cap, hd),
                         lambda b, i, idx_ref: (b, idx_ref[b, i], 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, cap, hd), lambda b, i, idx_ref: (b, i, 0, 0)),
            pl.BlockSpec((1, 1, cap, hd), lambda b, i, idx_ref: (b, i, 0, 0)),
        ],
    )
    return pl.pallas_call(
        _copy_kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((BH, r, cap, hd), k_store.dtype),
            jax.ShapeDtypeStruct((BH, r, cap, hd), v_store.dtype),
        ],
        interpret=interpret,
    )(idx, k_store, v_store)
