"""Pallas kernel analysis (RL201-RL203) over the wave-attention kernels.

* RL201 — a bounded model check of the double-buffered DMA cluster walk:
  the kernel AST is symbolically executed (the ``dmas`` helper inlined, the
  ``fori_loop`` body unrolled for a model trip count, ``pl.when`` guards
  evaluated where concrete), producing a start/wait/read event sequence per
  (scratch buffer, slot). A slot state machine then rejects reads of
  un-awaited slots, DMA starts into in-flight or unread slots, waits with
  nothing in flight, and copies left in flight at kernel end.
* RL202 — BlockSpec index maps restricted to pure index arithmetic (grid
  indices, scalar-prefetch subscripts, and a short allowlist of clamping
  helpers).
* RL203 — a static VMEM footprint estimate per kernel builder: every
  ``pltpu.VMEM`` scratch allocation plus 2x (pipeline double buffering) each
  BlockSpec block, with symbolic dims resolved from a geometry env, held
  against a configurable budget.

All three are deliberately conservative about what they can't resolve: an
unevaluable ``pl.when`` guard is assumed taken, an unknown dim resolves to a
generous default — the goal is catching the silent-on-CPU bug classes
(interpret mode serializes DMAs, so no test sees a wait-before-reuse race).
"""
from __future__ import annotations

import ast
import os
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.analysis.findings import Finding, Pragmas

# geometry env for symbolic dims in scratch/block shapes (paper-scale
# defaults; override via --geometry). Unknown names fall back to _default —
# generous, so an unmodeled dim over-counts rather than hides.
GEOMETRY_DEFAULTS: Dict[str, int] = {
    "G": 8, "hd": 128, "cap": 128, "block_l": 512, "block_t": 512,
    "Ss": 128, "E": 512, "r": 16, "dtype_bytes": 4, "_default": 128,
}
DEFAULT_VMEM_BUDGET = 16 * 1024 * 1024      # 16 MiB per-core VMEM

_MODEL_TRIPS = 4        # unrolled fori_loop iterations for the DMA model

_DTYPE_BYTES = {"float32": 4, "int32": 4, "uint32": 4, "bfloat16": 2,
                "float16": 2, "int8": 1, "uint8": 1, "float64": 8,
                "int64": 8, "bool_": 1, "bool": 1}

_INDEX_MAP_CALLS = {
    ("jnp", "clip"), ("jnp", "minimum"), ("jnp", "maximum"),
    ("jnp", "where"), ("jax", "lax", "rem"), ("jax", "lax", "div"),
    ("lax", "rem"), ("lax", "div"), ("pl", "ds"), ("pl", "dslice"),
    ("pl", "multiple_of"),
}


def _chain(node: ast.AST) -> Tuple[str, ...]:
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
    return tuple(reversed(parts))


# ============================================================ RL201 DMA model
@dataclass
class _Copy:
    dst_base: str
    dst_idx: ast.expr
    sem_idx: Optional[ast.expr]
    lineno: int


@dataclass
class _Helper:
    params: List[str]
    copies: List[_Copy]


class _DmaModel:
    """Bounded symbolic executor for one kernel function."""

    NEVER, INFLIGHT, READY, CONSUMED = "never", "inflight", "ready", "read"

    def __init__(self, fn: ast.FunctionDef, path: str, pragmas: Pragmas,
                 trips: int = _MODEL_TRIPS) -> None:
        self.fn = fn
        self.path = path
        self.pragmas = pragmas
        self.env: Dict[str, Any] = {"r": trips}
        self.trips = trips
        self.helpers: Dict[str, _Helper] = {}
        self.funcs: Dict[str, ast.FunctionDef] = {}
        self.state: Dict[Tuple[str, Any], str] = {}
        self.findings: List[Finding] = []
        self.dst_bases: set = set()
        for node in ast.walk(fn):       # pre-scan: which refs are DMA dsts
            if isinstance(node, ast.Call) \
                    and _chain(node.func)[-1:] == ("make_async_copy",) \
                    and len(node.args) >= 2:
                base, _ = self._ref_slot(node.args[1])
                if base:
                    self.dst_bases.add(base)

    # ------------------------------------------------------------- utilities
    @staticmethod
    def _ref_slot(node: ast.AST) -> Tuple[Optional[str], Optional[ast.expr]]:
        """``ref.at[idx]`` / ``ref[idx]`` -> (ref name, idx expr)."""
        if isinstance(node, ast.Subscript):
            tgt = node.value
            if isinstance(tgt, ast.Attribute) and tgt.attr == "at" \
                    and isinstance(tgt.value, ast.Name):
                return tgt.value.id, node.slice
            if isinstance(tgt, ast.Name):
                return tgt.id, node.slice
        if isinstance(node, ast.Name):
            return node.id, None
        return None, None

    def _eval(self, node: Optional[ast.AST]) -> Any:
        if node is None:
            return None
        if isinstance(node, ast.Constant):
            return node.value
        if isinstance(node, ast.Name):
            return self.env.get(node.id)
        if isinstance(node, ast.BinOp):
            a, b = self._eval(node.left), self._eval(node.right)
            if a is None or b is None:
                return None
            try:
                if isinstance(node.op, ast.Add):
                    return a + b
                if isinstance(node.op, ast.Sub):
                    return a - b
                if isinstance(node.op, ast.Mult):
                    return a * b
                if isinstance(node.op, ast.FloorDiv):
                    return a // b
                if isinstance(node.op, ast.Mod):
                    return a % b
            except ZeroDivisionError:
                return None
            return None
        if isinstance(node, ast.UnaryOp):
            v = self._eval(node.operand)
            if v is None:
                return None
            if isinstance(node.op, ast.USub):
                return -v
            if isinstance(node.op, ast.Not):
                return not v
            return None
        if isinstance(node, ast.Compare) and len(node.ops) == 1:
            a, b = self._eval(node.left), self._eval(node.comparators[0])
            if a is None or b is None:
                return None
            op = node.ops[0]
            if isinstance(op, ast.Lt):
                return a < b
            if isinstance(op, ast.LtE):
                return a <= b
            if isinstance(op, ast.Gt):
                return a > b
            if isinstance(op, ast.GtE):
                return a >= b
            if isinstance(op, ast.Eq):
                return a == b
            if isinstance(op, ast.NotEq):
                return a != b
            return None
        if isinstance(node, ast.Call):
            ch = _chain(node.func)
            if ch[-1:] == ("rem",) and len(node.args) == 2:
                a, b = self._eval(node.args[0]), self._eval(node.args[1])
                return None if a is None or b is None or b == 0 else a % b
            if ch[-1:] in (("clip",), ("minimum",), ("maximum",)):
                return None      # index arithmetic, value not needed
        return None

    def _flag(self, lineno: int, msg: str) -> None:
        if not self.pragmas.ignores(lineno, "RL201"):
            self.findings.append(Finding(
                "RL201", self.path, lineno, self.fn.name, msg))

    # --------------------------------------------------------- event machine
    def _event(self, op: str, base: str, slot: Any, lineno: int) -> None:
        key = (base, slot)
        st = self.state.get(key, self.NEVER)
        if op == "start":
            if st == self.INFLIGHT:
                self._flag(lineno,
                           f"DMA started into `{base}` slot {slot} while a "
                           f"previous copy into it is still in flight")
            elif st == self.READY:
                self._flag(lineno,
                           f"DMA started into `{base}` slot {slot} whose "
                           f"previous contents were never folded — unread "
                           f"data would be overwritten")
            self.state[key] = self.INFLIGHT
        elif op == "wait":
            if st != self.INFLIGHT:
                self._flag(lineno,
                           f"wait() on `{base}` slot {slot} with no DMA in "
                           f"flight (hangs on hardware)")
            else:
                self.state[key] = self.READY
        elif op == "read":
            if st == self.INFLIGHT:
                self._flag(lineno,
                           f"`{base}` slot {slot} read before its DMA was "
                           f"awaited — wait-before-reuse violated")
            elif st == self.NEVER:
                self._flag(lineno,
                           f"`{base}` slot {slot} read but no DMA ever "
                           f"filled it")
            elif st == self.READY:
                self.state[key] = self.CONSUMED

    def _finish(self) -> None:
        for (base, slot), st in sorted(self.state.items(),
                                       key=lambda kv: str(kv[0])):
            if st == self.INFLIGHT:
                self._flag(self.fn.end_lineno or self.fn.lineno,
                           f"DMA into `{base}` slot {slot} still in flight "
                           f"at kernel end (never awaited)")

    # ------------------------------------------------------------- execution
    def _scan_reads(self, node: ast.AST) -> None:
        for sub in ast.walk(node):
            if isinstance(sub, ast.Subscript) \
                    and isinstance(sub.ctx, ast.Load) \
                    and isinstance(sub.value, ast.Name) \
                    and sub.value.id in self.dst_bases:
                self._event("read", sub.value.id, self._eval(sub.slice),
                            sub.lineno)

    def _maybe_helper(self, fd: ast.FunctionDef) -> bool:
        copies = []
        for node in ast.walk(fd):
            if isinstance(node, ast.Call) \
                    and _chain(node.func)[-1:] == ("make_async_copy",) \
                    and len(node.args) >= 2:
                base, idx = self._ref_slot(node.args[1])
                sem_idx = None
                if len(node.args) >= 3:
                    _, sem_idx = self._ref_slot(node.args[2])
                if base:
                    copies.append(_Copy(base, idx, sem_idx, node.lineno))
        if copies:
            self.helpers[fd.name] = _Helper(
                [a.arg for a in fd.args.args], copies)
            return True
        return False

    def _emit_helper(self, helper: _Helper, args: List[ast.expr],
                     op: str, lineno: int) -> None:
        binding = {p: self._eval(a) for p, a in zip(helper.params, args)}
        saved = {p: self.env.get(p) for p in binding}
        self.env.update(binding)
        try:
            for copy in helper.copies:
                slot = self._eval(copy.dst_idx)
                if op == "start" and copy.sem_idx is not None:
                    if ast.dump(copy.dst_idx) != ast.dump(copy.sem_idx):
                        self._flag(copy.lineno,
                                   f"`{copy.dst_base}` DMA destination slot "
                                   f"and its semaphore slot differ — the "
                                   f"wait would not cover this copy")
                self._event(op, copy.dst_base, slot, lineno)
        finally:
            self.env.update(saved)

    def _when_cond(self, fd: ast.FunctionDef) -> Optional[ast.expr]:
        for dec in fd.decorator_list:
            if isinstance(dec, ast.Call) \
                    and _chain(dec.func)[-1:] == ("when",) and dec.args:
                return dec.args[0]
        return None

    def _exec(self, stmts: Sequence[ast.stmt]) -> None:
        for st in stmts:
            if isinstance(st, ast.FunctionDef):
                cond = self._when_cond(st)
                if cond is not None:        # pl.when body runs in place
                    if self._eval(cond) is not False:
                        self._exec(st.body)
                elif not self._maybe_helper(st):
                    self.funcs[st.name] = st
            elif isinstance(st, ast.Assign):
                self._handle_call(st.value)
                self._scan_reads(st.value)
                val = self._eval(st.value)
                for t in st.targets:
                    if isinstance(t, ast.Name):
                        self.env[t.id] = val
            elif isinstance(st, ast.Expr):
                if not self._handle_call(st.value):
                    self._scan_reads(st.value)
            elif isinstance(st, ast.For):
                if not self._handle_dma_for(st):
                    self._scan_reads(st.iter)
                    self._exec(st.body)
            elif isinstance(st, ast.If):
                c = self._eval(st.test)
                if c is not False:
                    self._exec(st.body)
                if c is not True:
                    self._exec(st.orelse)
            elif isinstance(st, ast.Return):
                if st.value is not None:
                    self._scan_reads(st.value)
            elif isinstance(st, (ast.With,)):
                self._exec(st.body)

    def _handle_dma_for(self, st: ast.For) -> bool:
        """``for c in dmas(slot, jc): c.start()/c.wait()``"""
        it = st.iter
        if not (isinstance(it, ast.Call) and isinstance(it.func, ast.Name)
                and it.func.id in self.helpers):
            return False
        op = None
        for node in ast.walk(ast.Module(body=list(st.body),
                                        type_ignores=[])):
            if isinstance(node, ast.Call) \
                    and isinstance(node.func, ast.Attribute) \
                    and node.func.attr in ("start", "wait"):
                op = node.func.attr
        if op is None:
            return False
        self._emit_helper(self.helpers[it.func.id], it.args, op, st.lineno)
        return True

    def _handle_call(self, expr: ast.AST) -> bool:
        """fori_loop unrolling + direct copy.start()/.wait() calls."""
        if not isinstance(expr, ast.Call):
            return False
        ch = _chain(expr.func)
        if ch[-1:] == ("fori_loop",) and len(expr.args) >= 3:
            lo = self._eval(expr.args[0])
            hi = self._eval(expr.args[1])
            body = expr.args[2]
            lo = 0 if lo is None else lo
            hi = self.trips if hi is None else hi
            if isinstance(body, ast.Name) and body.id in self.funcs:
                fd = self.funcs[body.id]
                ivar = fd.args.args[0].arg if fd.args.args else None
                for i in range(lo, min(hi, lo + 8)):
                    if ivar:
                        self.env[ivar] = i
                    self._exec(fd.body)
                return True
        # pltpu.make_async_copy(...).start() inline
        if isinstance(expr.func, ast.Attribute) \
                and expr.func.attr in ("start", "wait") \
                and isinstance(expr.func.value, ast.Call) \
                and _chain(expr.func.value.func)[-1:] \
                == ("make_async_copy",):
            mk = expr.func.value
            if len(mk.args) >= 2:
                base, idx = self._ref_slot(mk.args[1])
                if base:
                    self._event(expr.func.attr, base, self._eval(idx),
                                expr.lineno)
                    return True
        return False

    def run(self) -> List[Finding]:
        self._exec(self.fn.body)
        self._finish()
        return self.findings


def check_dma_discipline(tree: ast.Module, path: str, pragmas: Pragmas,
                         trips: int = _MODEL_TRIPS) -> List[Finding]:
    findings: List[Finding] = []
    seen: set = set()
    for node in tree.body:
        if isinstance(node, ast.FunctionDef) and any(
                isinstance(c, ast.Call)
                and _chain(c.func)[-1:] == ("make_async_copy",)
                for c in ast.walk(node)):
            # the unrolled model revisits each site once per trip — dedup
            for f in _DmaModel(node, path, pragmas, trips).run():
                key = (f.line, f.fingerprint)
                if key not in seen:
                    seen.add(key)
                    findings.append(f)
    return findings


# ====================================================== RL202 index-map purity
def _index_map_violation(fn_node, names: Dict[str, ast.expr]) -> Optional[str]:
    """None if pure; else a description of the first impurity."""
    if isinstance(fn_node, ast.Name):
        fn_node = names.get(fn_node.id)
        if fn_node is None:
            return None         # unresolvable reference: skip, don't guess
    if isinstance(fn_node, ast.Lambda):
        body: List[ast.AST] = [fn_node.body]
    elif isinstance(fn_node, ast.FunctionDef):
        body = list(fn_node.body)
        for st in body:
            if not isinstance(st, (ast.Return, ast.Expr)):
                return f"statement `{type(st).__name__}` in index map"
    else:
        return None
    allowed_call_roots: set = set()
    for node in [n for b in body for n in ast.walk(b)]:
        if isinstance(node, ast.Call):
            ch = _chain(node.func)
            if ch in _INDEX_MAP_CALLS:
                allowed_call_roots.add(id(node.func))
                continue
            return f"call to `{'.'.join(ch) or '<expr>'}`"
        if isinstance(node, (ast.Assign, ast.AugAssign, ast.NamedExpr)):
            return "assignment inside index map"
        if isinstance(node, (ast.Await, ast.Yield, ast.YieldFrom)):
            return f"`{type(node).__name__.lower()}` inside index map"
    return None


def check_index_maps(tree: ast.Module, path: str,
                     pragmas: Pragmas) -> List[Finding]:
    findings: List[Finding] = []
    # name -> lambda/def bindings, collected across every scope
    names: Dict[str, ast.expr] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Lambda):
            for t in node.targets:
                if isinstance(t, ast.Name):
                    names[t.id] = node.value
        elif isinstance(node, ast.FunctionDef):
            names.setdefault(node.name, node)
    for node in ast.walk(tree):
        if not (isinstance(node, ast.Call)
                and _chain(node.func)[-1:] == ("BlockSpec",)):
            continue
        index_map = node.args[1] if len(node.args) >= 2 else None
        for kw in node.keywords:
            if kw.arg == "index_map":
                index_map = kw.value
        if index_map is None:
            continue
        why = _index_map_violation(index_map, names)
        if why and not pragmas.ignores(node.lineno, "RL202"):
            findings.append(Finding(
                "RL202", path, node.lineno, "<BlockSpec>",
                f"index map is not pure index arithmetic: {why}"))
    return findings


# ========================================================= RL203 VMEM budget
def _dim_value(node: ast.AST, geom: Dict[str, int]) -> int:
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return node.value
    if isinstance(node, ast.Name):
        return geom.get(node.id, geom.get("_default", 128))
    if isinstance(node, ast.BinOp):
        a, b = _dim_value(node.left, geom), _dim_value(node.right, geom)
        if isinstance(node.op, ast.Add):
            return a + b
        if isinstance(node.op, ast.Sub):
            return max(a - b, 0)
        if isinstance(node.op, ast.Mult):
            return a * b
        if isinstance(node.op, ast.FloorDiv):
            return a // max(b, 1)
        if isinstance(node.op, ast.Mod):
            return a % max(b, 1)
    return geom.get("_default", 128)


def _shape_bytes(shape_node: ast.AST, dtype_node: Optional[ast.AST],
                 geom: Dict[str, int]) -> int:
    if not isinstance(shape_node, (ast.Tuple, ast.List)):
        return 0
    n = 1
    for el in shape_node.elts:
        n *= max(_dim_value(el, geom), 1)
    itemsize = geom.get("dtype_bytes", 4)
    if dtype_node is not None:
        ch = _chain(dtype_node)
        if ch and ch[-1] in _DTYPE_BYTES:
            itemsize = _DTYPE_BYTES[ch[-1]]
    return n * itemsize


def check_vmem_budget(tree: ast.Module, path: str, pragmas: Pragmas,
                      geometry: Optional[Dict[str, int]] = None,
                      budget: int = DEFAULT_VMEM_BUDGET) -> List[Finding]:
    geom = dict(GEOMETRY_DEFAULTS)
    geom.update(geometry or {})
    findings: List[Finding] = []
    for fn in tree.body:
        if not isinstance(fn, ast.FunctionDef):
            continue
        total = 0
        n_sites = 0
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            ch = _chain(node.func)
            if ch[-1:] == ("VMEM",) and node.args:
                total += _shape_bytes(node.args[0],
                                      node.args[1] if len(node.args) > 1
                                      else None, geom)
                n_sites += 1
            elif ch[-1:] == ("BlockSpec",) and node.args:
                # the automatic pipeline double-buffers every block
                total += 2 * _shape_bytes(node.args[0], None, geom)
                n_sites += 1
        if n_sites and total > budget \
                and not pragmas.ignores(fn.lineno, "RL203"):
            findings.append(Finding(
                "RL203", path, fn.lineno, fn.name,
                f"estimated VMEM footprint {total} bytes exceeds the "
                f"{budget}-byte budget at the checked geometry "
                f"({n_sites} scratch/block sites)"))
    return findings


# ------------------------------------------------------------------- drivers
def check_source(source: str, path: str,
                 geometry: Optional[Dict[str, int]] = None,
                 vmem_budget: int = DEFAULT_VMEM_BUDGET) -> List[Finding]:
    tree = ast.parse(source, filename=path)
    pragmas = Pragmas.scan(source)
    findings = check_dma_discipline(tree, path, pragmas)
    findings += check_index_maps(tree, path, pragmas)
    findings += check_vmem_budget(tree, path, pragmas, geometry, vmem_budget)
    return findings


def check_tree(root: str, subdir: str = "src/repro/kernels",
               geometry: Optional[Dict[str, int]] = None,
               vmem_budget: int = DEFAULT_VMEM_BUDGET) -> List[Finding]:
    findings: List[Finding] = []
    base = os.path.join(root, subdir)
    for dirpath, _dirs, files in os.walk(base):
        for name in sorted(files):
            if not name.endswith(".py"):
                continue
            full = os.path.join(dirpath, name)
            rel = os.path.relpath(full, root).replace(os.sep, "/")
            with open(full) as f:
                findings += check_source(f.read(), rel, geometry, vmem_budget)
    return findings
