"""retrosched — RL301-RL305 happens-before model checks over the offload
decode schedule.

The event/effects model lives in ``schedule_model``; this module holds the
rules. ``check_trace`` runs them over a ``ScheduleTrace`` — recorded from a
real serve run (``ScheduleRecorder`` hooks ``_OffloadPlane.trace``) or seeded
from an op-sequence fixture (``schedule_model.build_trace``); both paths
resolve effects through the same ``SERVE_STAGES`` declarations, so a fixture
exercises exactly the model the engine is held to.

Rules (error unless noted):

* RL301 — a dispatch reads the miss staging tail (or a host-built payload)
  whose same-step write has not happened-before it;
* RL302 — a deferred-admission drain remapped the ClusterMappingTable but no
  ``cache_upd`` consumed its admission queue before the next attend on that
  layer (the device cache lags the table: translated slot ids point at
  whatever the evicted cluster left behind);
* RL303 — a host-space write lands in a device buffer while a dispatched
  reader of that buffer is not yet proven complete (no sync edge);
* RL304 — (advice) the pipeline-opportunity detector: a blocking readback
  with an idle host-order gap while independent host work sits just before
  the producer — that work could legally overlap the sync;
* RL305 — a donated buffer is read or re-donated before being rebound.
"""
from __future__ import annotations

from typing import Callable, List, Optional

from repro.analysis.findings import Finding
from repro.analysis.schedule_model import (Event, ScheduleRecorder,
                                           ScheduleTrace, buffer_base,
                                           buffer_space)

ENGINE_PATH = "src/repro/serving/engine.py"


def _finding(rule: str, event: Event, message: str,
             severity: str = "error") -> Finding:
    qual = f"{event.op}" + (f"/L{event.layer}" if event.layer >= 0 else "")
    return Finding(rule, ENGINE_PATH, 0, qual, message, severity=severity)


def _last_host_writer(tr: ScheduleTrace, buf: str,
                      before_seq: int) -> Optional[Event]:
    best = None
    for e in tr.events:
        if e.seq >= before_seq:
            break
        if e.kind == "host" and buf in e.writes:
            best = e
    return best


# ----------------------------------------------------------------- RL301
def _check_staging_order(tr: ScheduleTrace, out: List[Finding]) -> None:
    for d in tr.dispatches:
        for buf in d.reads:
            if buf in d.writes:
                continue        # read-modify-write: the event IS the stager
            if buffer_base(buf) == "cache_tail":
                w = tr.last_device_writer(buf, d.seq)
                if w is None or w.step != d.step or w.layer != d.layer:
                    stale = "no staging write at all" if w is None else \
                        f"last write is {w.qual()}"
                    out.append(_finding(
                        "RL301", d,
                        f"{d.qual()} reads the miss staging tail {buf} but "
                        f"this step's staging write has not landed on the "
                        f"stream before it ({stale}) — the attend would "
                        f"consume the previous step's staged clusters"))
            elif buffer_space(buf) == "link":
                t = _last_host_writer(tr, buf, d.seq)
                if t is None or t.step != d.step or t.layer != d.layer:
                    src = "never built" if t is None else \
                        f"last built by {t.qual()}"
                    out.append(_finding(
                        "RL301", d,
                        f"{d.qual()} consumes host-built payload {buf} "
                        f"({src}) — the dispatch was issued before this "
                        f"step's translate produced it"))


# ----------------------------------------------------------------- RL302
def _check_mirror_edge(tr: ScheduleTrace, out: List[Finding]) -> None:
    for i, e in enumerate(tr.events):
        if e.op != "drain_admissions":
            continue
        for buf in e.writes:
            if buffer_base(buf) != "adm_queue":
                continue
            consumed = False
            for f in tr.events[i + 1:]:
                if f.op == "cache_upd" and buf in f.reads:
                    consumed = True
                if f.op == "attend_fn" and f.layer == e.layer:
                    if not consumed:
                        out.append(_finding(
                            "RL302", e,
                            f"{e.qual()} remapped mapping-table entries and "
                            f"queued {buf}, but no cache_upd consumed the "
                            f"queue before {f.qual()} — translated slot ids "
                            f"point at clusters the device cache no longer "
                            f"holds"))
                    break


# ----------------------------------------------------------------- RL303
def _check_inflight_overwrite(tr: ScheduleTrace, out: List[Finding]) -> None:
    pos = tr.stream_pos()
    for e in tr.events:
        if e.kind != "host":
            continue
        dev_writes = [b for b in e.writes if buffer_space(b) == "device"]
        if not dev_writes:
            continue
        done = tr.completed_stream_prefix(e.seq)
        for buf in dev_writes:
            inflight = [d for d in tr.dispatches
                        if d.seq < e.seq and buf in d.reads
                        and pos[d.seq] >= done]
            if inflight:
                out.append(_finding(
                    "RL303", e,
                    f"{e.qual()} writes device buffer {buf} off the stream "
                    f"while {inflight[-1].qual()} (dispatched, not proven "
                    f"complete by any sync) still reads it — route the "
                    f"mirror through a jitted stage so the stream orders "
                    f"them"))


# ----------------------------------------------------------------- RL304
def _check_pipeline_opportunity(tr: ScheduleTrace,
                                out: List[Finding]) -> None:
    pos = tr.stream_pos()
    for s in tr.events:
        if s.kind != "sync":
            continue
        producer = None
        for buf in s.reads:
            if buffer_space(buf) != "device":
                continue
            w = tr.last_device_writer(buf, s.seq)
            if w is not None and (producer is None
                                  or pos[w.seq] > pos[producer.seq]):
                producer = w
        if producer is None:
            continue
        gap_work = [e for e in tr.events
                    if producer.seq < e.seq < s.seq
                    and e.kind == "host" and e.writes]
        if gap_work:
            continue                # the sync already overlaps host work
        hoistable = None
        for e in tr.events:
            if e.seq >= producer.seq:
                break
            if e.kind == "host" and e.writes and e.step == producer.step:
                hoistable = e
        if hoistable is None or tr.depends(hoistable, producer):
            continue
        out.append(_finding(
            "RL304", s,
            f"{s.qual()} blocks with an idle host while {hoistable.qual()} "
            f"(no dependency path into {producer.qual()}) sits before the "
            f"producer — dispatch {producer.op} first and run "
            f"{hoistable.op} inside the gap to overlap the readback",
            severity="advice"))


# ----------------------------------------------------------------- RL305
def _check_donation_reuse(tr: ScheduleTrace, out: List[Finding]) -> None:
    for i, e in enumerate(tr.events):
        for buf in e.donates:
            if buf in e.writes or buf in e.passes:
                continue            # rebound by the donating op itself
            for f in tr.events[i + 1:]:
                if buf in f.writes or buf in f.passes:
                    break           # rebound before any reuse
                if buf in f.reads or buf in f.donates:
                    out.append(_finding(
                        "RL305", f,
                        f"{f.qual()} uses {buf} after {e.qual()} donated it "
                        f"without rebinding — once layers overlap the "
                        f"buffer is clobbered device memory"))
                    break


_CHECKS: List[Callable[[ScheduleTrace, List[Finding]], None]] = [
    _check_staging_order, _check_mirror_edge, _check_inflight_overwrite,
    _check_pipeline_opportunity, _check_donation_reuse,
]


def check_trace(trace: ScheduleTrace) -> List[Finding]:
    """All RL3xx rules over one schedule, deduped by fingerprint (per-step
    repeats of one defect collapse to a single finding)."""
    raw: List[Finding] = []
    for check in _CHECKS:
        check(trace, raw)
    seen, out = set(), []
    for f in raw:
        if f.fingerprint not in seen:
            seen.add(f.fingerprint)
            out.append(f)
    return out


def schedule_findings(trace: Optional[ScheduleTrace]) -> List[Finding]:
    """``check_trace`` with the recorded-nothing case surfaced as its own
    error: an offload serve run that produced no events means the trace
    hooks were removed or the plane was bypassed, and the schedule is
    unverified."""
    if trace is None or not trace.events:
        return [Finding(
            "RL301", ENGINE_PATH, 0, "_OffloadPlane",
            "offload serve run recorded no schedule events — trace hooks "
            "missing, so the decode schedule cannot be certified")]
    return check_trace(trace)


# --------------------------------------------------------------- fixtures
def reference_schedule(n_layers: int = 2, steps: int = 2, *,
                       pipelined: bool = True, warm: bool = False,
                       drop_mirror: bool = False) -> List[tuple]:
    """The offload decode schedule as ``(step, layer, op, kind[, extras])``
    tuples. ``pipelined=True`` is the shipped engine order (layer l+1's rank
    dispatched and readback started before layer l's drain);
    ``pipelined=False`` is the pre-pipeline order that RL304 flags;
    ``warm=True`` drains nothing (all hits); ``drop_mirror=True`` seeds the
    RL302 bug (admissions queued but staged with ``cache_stage``)."""
    sched: List[tuple] = []
    for t in range(steps):
        sched.append((t, -1, "embed_tokens", "dispatch"))
        if pipelined:
            sched.append((t, 0, "rank_fn", "dispatch"))
            sched.append((t, 0, "readback_start", "host"))
        for layer in range(n_layers):
            if not pipelined:
                sched.append((t, layer, "rank_fn", "dispatch"))
            sched.append((t, layer, "readback_ids", "sync"))
            sched.append((t, layer, "translate", "host"))
            upd = "cache_upd" if (t > 0 and not warm and not drop_mirror) \
                else "cache_stage"
            sched.append((t, layer, upd, "dispatch"))
            sched.append((t, layer, "attend_fn", "dispatch"))
            if pipelined and layer + 1 < n_layers:
                sched.append((t, layer + 1, "rank_fn", "dispatch"))
                sched.append((t, layer + 1, "readback_start", "host"))
            sched.append((t, layer, "drain_admissions", "host",
                          {"queued": not warm}))
        sched.append((t, -1, "unembed_logits", "dispatch"))
    return sched


# ----------------------------------------------------------- live serve run
def run_schedule_checks(verbose=None) -> List[Finding]:
    """Standalone gate: record the schedule of a real tiny offload serve run
    and model-check it. The lint CLI reaches the same check through
    ``jaxpr_check.run_contract_checks`` (one recorder wraps the existing
    offload run); this entrypoint serves tests and ad-hoc use."""
    from repro.analysis.jaxpr_check import _requests, _tiny_setup
    from repro.serving.engine import ServeEngine
    log = verbose or (lambda *_: None)
    cfg, params = _tiny_setup()
    log("retrosched: recording offload serve schedule")
    with ScheduleRecorder() as rec:
        engine = ServeEngine(cfg, params, gen_headroom=256,
                             admission="chunked", offload=True,
                             temperature=0.0)
        engine.serve(_requests([48, 72, 96, 72], 40), batch_size=2, seed=0)
    log("retrosched: model-checking the recorded schedule")
    return schedule_findings(rec.trace)
