"""retrosched event/effects model — the happens-before graph of the offload
decode schedule (rules RL301-RL305 live in ``schedule_check``).

The serve engine's offload control plane interleaves four actors: the single
device stream (jitted stages, executed asynchronously in dispatch order), the
host thread (translation, deferred-admission drains, payload packing), the
host->device transfers folded into each dispatch, and the device->host
readbacks (the only points where the host learns device state). PR 6's
``SERVE_STAGES`` contract named each stage's donations and compile budget;
this module extends it to *effects*: the abstract buffers a stage reads,
writes, donates, or passes through, and which memory space each buffer lives
in. From a recorded schedule (``ScheduleRecorder`` hooks the real
``_OffloadPlane``) it builds the event list the model checker runs over.

Happens-before, as the checker uses it:

* host events (including dispatch *issuance*) are totally ordered by ``seq``;
* device *execution* of dispatches is totally ordered by dispatch order (one
  in-order stream);
* a dispatch executes after its own issuance (so after every earlier host
  event);
* a ``sync`` event on a device value completes after the producing dispatch
  executed — and, stream order being total, after every dispatch issued
  before the producer.

Buffers are strings like ``"cache_body[3]"``: a base name from
``BUFFER_SPACE`` plus the layer instance. Stage declarations use ``[l]``
(the event's layer) or ``[*]`` (every layer); layer-free buffers
(``hidden``, ``tokens``) have no suffix.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

# Memory space of each abstract buffer, by base name. "device" buffers are
# only legally written by dispatched stages (the stream serializes them);
# "host" buffers are only touched by host-thread ops; "link" buffers are
# host-built payloads consumed by a dispatch at issuance (the host->device
# transfer is part of the dispatch).
BUFFER_SPACE: Dict[str, str] = {
    # device
    "hidden": "device", "live": "device", "ids": "device", "ctx": "device",
    "cache_body": "device", "cache_tail": "device", "logits": "device",
    "tokens": "device", "serve_state": "device", "slot_state": "device",
    "chunk_state": "device", "prompt": "device", "flush_blocks": "device",
    # host
    "ids_host": "host", "cmt": "host", "host_store": "host",
    "pending": "host", "adm_queue": "host",
    # host-built, consumed by a dispatch at issuance ("valid" is the
    # per-cluster fetch-validity mask of the degraded decode path: built by
    # translate, read by the same step's attend — RL301 certifies the order)
    "slots": "link", "miss": "link", "valid": "link",
}

# Host control-plane ops of the offload decode step. These are not jitted
# stages (no donate/budget contract) but they ARE schedule events; the
# engine registers them in SERVE_STAGES with space="host" so the whole
# schedule contract lives in one table.
HOST_OP_KINDS = ("host", "sync")


def buffer_base(buf: str) -> str:
    return buf.split("[", 1)[0]


def buffer_space(buf: str) -> str:
    return BUFFER_SPACE.get(buffer_base(buf), "host")


@dataclass(frozen=True)
class Event:
    """One schedule event with fully resolved effects.

    ``kind``: "dispatch" (device stage, issued here, executed on the stream),
    "host" (host-thread compute), or "sync" (host blocks on a device value).
    ``passes`` are donated-and-carried buffers: the output aliases the input
    bit-for-bit (``cache_stage`` passing the cache body through), which
    rebinds the reference without counting as a data write.
    """
    seq: int
    step: int
    layer: int
    op: str
    kind: str
    reads: Tuple[str, ...] = ()
    writes: Tuple[str, ...] = ()
    donates: Tuple[str, ...] = ()
    passes: Tuple[str, ...] = ()

    def qual(self) -> str:
        at = f"@step{self.step}" + (f"/L{self.layer}" if self.layer >= 0
                                    else "")
        return f"{self.op}{at}"


def _resolve_one(name: str, layer: int, n_layers: int) -> Tuple[str, ...]:
    if name.endswith("[l]"):
        if layer < 0:
            raise ValueError(f"effect {name!r} needs a layer, event has none")
        return (f"{name[:-3]}[{layer}]",)
    if name.endswith("[*]"):
        return tuple(f"{name[:-3]}[{i}]" for i in range(n_layers))
    return (name,)


def resolve_effects(effects: Dict[str, Sequence[str]], layer: int,
                    n_layers: int) -> Dict[str, Tuple[str, ...]]:
    """Substitute ``[l]``/``[*]`` placeholders for one event instance."""
    out: Dict[str, Tuple[str, ...]] = {}
    for slot in ("reads", "writes", "donates", "passes"):
        resolved: List[str] = []
        for name in effects.get(slot, ()):
            resolved.extend(_resolve_one(name, layer, n_layers))
        out[slot] = tuple(resolved)
    return out


def make_event(seq: int, step: int, layer: int, op: str, kind: str,
               n_layers: int, stage_table: Dict[str, Dict[str, Any]],
               extras: Optional[Dict[str, Any]] = None) -> Event:
    """Build one resolved event from a stage-table entry (or raw effects
    passed via ``extras["effects"]`` for ops outside the table — used by the
    selftest fixtures to seed pathological schedules)."""
    extras = extras or {}
    if "effects" in extras:
        effects = dict(extras["effects"])
    else:
        contract = stage_table.get(op)
        if contract is None or "effects" not in contract:
            raise KeyError(f"op {op!r} has no effects declaration in the "
                           f"stage table — every schedule event must declare "
                           f"its effects (see SERVE_STAGES)")
        effects = dict(contract["effects"])
    eff = resolve_effects(effects, layer, n_layers)
    # dynamic refinement: a drain that queued nothing remapped nothing (its
    # writes would otherwise claim an admission mirror that never exists,
    # tripping RL302 on every warm-cache step)
    if extras.get("queued") is False:
        eff["writes"] = tuple(b for b in eff["writes"]
                              if buffer_base(b) not in ("adm_queue", "cmt"))
    return Event(seq=seq, step=step, layer=layer, op=op, kind=kind,
                 reads=eff["reads"], writes=eff["writes"],
                 donates=eff["donates"], passes=eff["passes"])


@dataclass
class ScheduleTrace:
    """A recorded (or seeded) schedule: events in host order, plus the
    derived device-stream order of the dispatches."""
    n_layers: int
    events: List[Event] = field(default_factory=list)

    @property
    def dispatches(self) -> List[Event]:
        return [e for e in self.events if e.kind == "dispatch"]

    def stream_pos(self) -> Dict[int, int]:
        """seq -> position on the device stream (dispatches only)."""
        return {e.seq: i for i, e in enumerate(self.dispatches)}

    def last_device_writer(self, buf: str, before_seq: int
                           ) -> Optional[Event]:
        """Latest dispatch (stream order == host issuance order) writing or
        passing ``buf`` issued before ``before_seq``."""
        best = None
        for e in self.dispatches:
            if e.seq >= before_seq:
                break
            if buf in e.writes or buf in e.passes:
                best = e
        return best

    def completed_stream_prefix(self, at_seq: int) -> int:
        """Number of leading stream dispatches PROVEN complete at host time
        ``at_seq``: the largest stream position synced on, plus one. A sync
        on a value produced by dispatch P proves every dispatch issued up to
        and including P has executed."""
        pos = self.stream_pos()
        done = 0
        for e in self.events:
            if e.seq >= at_seq:
                break
            if e.kind != "sync":
                continue
            for buf in e.reads:
                if buffer_space(buf) != "device":
                    continue
                prod = self.last_device_writer(buf, e.seq)
                if prod is not None:
                    done = max(done, pos[prod.seq] + 1)
        return done

    def depends(self, a: Event, b: Event) -> bool:
        """True if a dependency chain (RAW/WAR/WAW through intermediate
        events) forces ``a`` to stay before ``b`` in host order."""
        assert a.seq < b.seq
        window = [e for e in self.events if a.seq <= e.seq <= b.seq]
        live = set(a.writes) | set(a.passes)
        if not live:
            return False
        for e in window[1:]:
            touched = set(e.reads) | set(e.writes) | set(e.donates)
            if live & touched:
                if e is b:
                    return True
                live |= set(e.writes) | set(e.passes)
        # WAR: b writes something a reads
        return bool((set(a.reads) | set(a.donates))
                    & (set(b.writes) | set(b.donates)))


class ScheduleRecorder:
    """Context manager hooking the real ``_OffloadPlane.trace`` no-op so a
    live offload serve run records its schedule (the StageRecorder idiom of
    the jaxpr pass, applied to the control plane)."""

    def __init__(self) -> None:
        self.trace: Optional[ScheduleTrace] = None
        self._raw: List[Tuple[int, int, str, str, Dict[str, Any]]] = []

    def __enter__(self) -> "ScheduleRecorder":
        from repro.serving import engine as _engine
        self._engine = _engine
        self._orig = _engine._OffloadPlane.trace
        recorder = self

        def tracing(plane, op, layer, kind, step, **extras):
            if recorder.trace is None:
                recorder.trace = ScheduleTrace(n_layers=plane.L)
            recorder._raw.append((step, layer, op, kind, extras))

        _engine._OffloadPlane.trace = tracing
        return self

    def __exit__(self, *exc) -> None:
        self._engine._OffloadPlane.trace = self._orig
        if self.trace is not None:
            table = self._engine.SERVE_STAGES
            for seq, (step, layer, op, kind, extras) in enumerate(self._raw):
                self.trace.events.append(make_event(
                    seq, step, layer, op, kind, self.trace.n_layers,
                    table, extras))


def build_trace(schedule: Iterable[Tuple], n_layers: int,
                stage_table: Optional[Dict[str, Dict[str, Any]]] = None
                ) -> ScheduleTrace:
    """Build a trace from ``(step, layer, op, kind[, extras])`` tuples — the
    fixture path: selftests seed good/bad schedules through the same
    resolver the recorder uses, so a fixture exercises exactly the model the
    real engine is held to."""
    if stage_table is None:
        from repro.serving.engine import SERVE_STAGES
        stage_table = SERVE_STAGES
    trace = ScheduleTrace(n_layers=n_layers)
    for seq, item in enumerate(schedule):
        step, layer, op, kind = item[:4]
        extras = item[4] if len(item) > 4 else None
        trace.events.append(make_event(seq, step, layer, op, kind, n_layers,
                                       stage_table, extras))
    return trace
