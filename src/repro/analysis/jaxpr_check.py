"""Trace-time contract checks (RL101-RL104) over the jitted serve stages.

Rather than hardcoding what the engine jits, the checker *records* it:
``StageRecorder`` monkeypatches ``jax.jit`` while a real (tiny-config) serve
run executes, capturing for every jit built at runtime its function name, the
jit kwargs (``donate_argnums``), the underlying jitted object, and the
argument avals of its first call. Stages registered in
``serving.engine.SERVE_STAGES`` are then held to their contract:

* RL101 — the stage jaxpr contains no callback / host-transfer primitive;
* RL102 — declared donations match the contract AND every donated leaf
  lowers to a real output alias (``tf.aliasing_output`` in the MLIR), with
  the "donated buffers were not usable" UserWarning treated as a violation;
* RL103 — across the run each stage compiles exactly its budgeted number of
  times (counted from the ``jax_log_compiles`` log stream);
* RL104 — (advice) an un-donated large input with an identically-shaped
  output, the usual signature of an in-place update paying a copy.

Everything runs on CPU with the tiny geometry below (same scale as the
tier-1 system tests); one full check is two short serve runs.
"""
from __future__ import annotations

import functools
import logging
import re
import warnings
from collections import Counter
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.tree_util as jtu
import numpy as np

from repro.analysis import numerics_check
from repro.analysis.findings import Finding

ENGINE_PATH = "src/repro/serving/engine.py"

_CALLBACK_TAGS = ("callback", "infeed", "outfeed")
_TRANSFER_PRIMS = {"device_put"}

_COMPILE_RE = re.compile(r"Compiling ([\w.<>\[\]-]+) with global shapes")

# RL104 only looks at inputs at least this large — below it a defensive copy
# is noise, not a throughput bug
_RL104_MIN_BYTES = 1 << 16


def _aval(x):
    if hasattr(x, "shape") and hasattr(x, "dtype"):
        return jax.ShapeDtypeStruct(tuple(x.shape), x.dtype)
    return jax.ShapeDtypeStruct(np.shape(x), np.asarray(x).dtype)


@dataclass
class StageRecord:
    name: str
    fn: Any
    jitted: Any
    jit_kwargs: Dict[str, Any]
    avals: Optional[Tuple] = None       # per-arg aval pytrees, first call


class StageRecorder:
    """Context manager: patch ``jax.jit`` to record every jit built (and the
    avals of its first call) while leaving behavior untouched."""

    def __init__(self) -> None:
        self.records: Dict[str, StageRecord] = {}

    def __enter__(self) -> "StageRecorder":
        self._orig = jax.jit
        recorder = self

        def recording_jit(fun=None, **kw):
            if fun is None:                     # jax.jit(**kw) decorator form
                return functools.partial(recording_jit, **kw)
            jitted = recorder._orig(fun, **kw)
            name = getattr(fun, "__name__", "<anonymous>")
            rec = recorder.records.setdefault(
                name, StageRecord(name, fun, jitted, dict(kw)))

            @functools.wraps(fun)
            def wrapper(*args, **kwargs):
                if rec.avals is None and not kwargs:
                    try:
                        rec.avals = tuple(jtu.tree_map(_aval, a)
                                          for a in args)
                    except (TypeError, ValueError):
                        pass
                return jitted(*args, **kwargs)

            wrapper._retrolint_jitted = jitted
            return wrapper

        jax.jit = recording_jit
        return self

    def __exit__(self, *exc) -> None:
        jax.jit = self._orig


class CompileLog:
    """Context manager counting XLA compilations per function name via the
    ``jax_log_compiles`` log stream (logger ``jax._src.interpreters.pxla``)."""

    def __init__(self) -> None:
        self.counts: Counter = Counter()

    def __enter__(self) -> "CompileLog":
        log = self

        class _H(logging.Handler):
            def emit(self, record):
                m = _COMPILE_RE.search(record.getMessage())
                if m:
                    log.counts[m.group(1)] += 1

        self._handler = _H()
        self._logger = logging.getLogger("jax._src.interpreters.pxla")
        self._logger.addHandler(self._handler)
        # jax_log_compiles elevates trace/compile logs to WARNING — keep
        # them out of the user's terminal while we count
        self._silenced = [self._logger,
                          logging.getLogger("jax._src.dispatch")]
        self._propagate = [lg.propagate for lg in self._silenced]
        self._null = logging.NullHandler()      # defeats logging.lastResort
        for lg in self._silenced:
            lg.propagate = False
            lg.addHandler(self._null)
        self._prev = jax.config.jax_log_compiles
        jax.config.update("jax_log_compiles", True)
        return self

    def __exit__(self, *exc) -> None:
        jax.config.update("jax_log_compiles", self._prev)
        self._logger.removeHandler(self._handler)
        for lg, p in zip(self._silenced, self._propagate):
            lg.propagate = p
            lg.removeHandler(self._null)


# ------------------------------------------------------------ per-stage checks
def _iter_subjaxprs(params: Dict[str, Any]):
    import jax.core as jcore
    for v in params.values():
        vals = v if isinstance(v, (list, tuple)) else (v,)
        for x in vals:
            if isinstance(x, jcore.ClosedJaxpr):
                yield x.jaxpr
            elif isinstance(x, jcore.Jaxpr):
                yield x


def _scan_jaxpr(jaxpr, hits: Counter) -> None:
    for eqn in jaxpr.eqns:
        pname = eqn.primitive.name
        if any(t in pname for t in _CALLBACK_TAGS) \
                or pname in _TRANSFER_PRIMS:
            hits[pname] += 1
        for sub in _iter_subjaxprs(eqn.params):
            _scan_jaxpr(sub, hits)


def callback_findings(fn, avals: Sequence, name: str,
                      path: str = ENGINE_PATH) -> List[Finding]:
    """RL101 over one traceable function at the given avals."""
    try:
        jaxpr = jax.make_jaxpr(fn)(*avals)
    except Exception as e:      # tracing failed: surface, don't crash the CLI
        return [Finding("RL101", path, 0, name,
                        f"stage could not be traced for inspection: {e!r}")]
    hits: Counter = Counter()
    _scan_jaxpr(jaxpr.jaxpr, hits)
    return [
        Finding("RL101", path, 0, name,
                f"stage traces host primitive `{prim}` x{n} — jitted serve "
                f"stages must be pure device compute")
        for prim, n in sorted(hits.items())]


def _norm_donate(d) -> Tuple[int, ...]:
    if d is None:
        return ()
    return (d,) if isinstance(d, int) else tuple(d)


def donation_findings(jitted, avals: Sequence, declared: Tuple[int, ...],
                      contract: Tuple[int, ...], name: str,
                      path: str = ENGINE_PATH) -> List[Finding]:
    """RL102 over one jitted stage: contract match + true aliasing."""
    findings: List[Finding] = []
    if tuple(sorted(declared)) != tuple(sorted(contract)):
        findings.append(Finding(
            "RL102", path, 0, name,
            f"stage declares donate_argnums={tuple(sorted(declared))} but "
            f"the serve contract requires {tuple(sorted(contract))} — an "
            f"in-place stage without its donation pays a full copy per "
            f"step"))
        return findings
    if not declared:
        return findings
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        try:
            mlir = jitted.lower(*avals).as_text()
        except Exception as e:
            return [Finding("RL102", path, 0, name,
                            f"stage could not be lowered for donation "
                            f"analysis: {e!r}")]
    unused = [w for w in caught
              if "donated" in str(w.message).lower()]
    donated_leaves = sum(len(jtu.tree_leaves(avals[i])) for i in declared
                         if i < len(avals))
    aliased = len(re.findall(r"tf\.aliasing_output", mlir))
    if unused or aliased < donated_leaves:
        findings.append(Finding(
            "RL102", path, 0, name,
            f"donation does not fully alias: {aliased}/{donated_leaves} "
            f"donated leaves carry tf.aliasing_output"
            + (f" (XLA: {unused[0].message})" if unused else "")))
    return findings


def missed_donation_findings(rec: StageRecord, contract: Tuple[int, ...],
                             path: str = ENGINE_PATH) -> List[Finding]:
    """RL104 (advice): large un-donated inputs with identically-shaped
    outputs."""
    if rec.avals is None:
        return []
    try:
        out = jax.eval_shape(rec.fn, *rec.avals)
    except Exception:
        return []
    out_shapes = {(tuple(leaf.shape), jtu.tree_leaves(leaf)[0].dtype.name
                   if hasattr(leaf, "dtype") else None)
                  for leaf in jtu.tree_leaves(out)
                  if hasattr(leaf, "shape")}
    findings = []
    for i, arg in enumerate(rec.avals):
        if i in contract:
            continue
        for leaf in jtu.tree_leaves(arg):
            if not hasattr(leaf, "shape"):
                continue
            nbytes = int(np.prod(leaf.shape, dtype=np.int64)) \
                * leaf.dtype.itemsize
            if nbytes < _RL104_MIN_BYTES:
                continue
            if (tuple(leaf.shape), leaf.dtype.name) in out_shapes:
                findings.append(Finding(
                    "RL104", path, 0, rec.name,
                    f"arg {i} has an un-donated {leaf.dtype.name}"
                    f"{tuple(leaf.shape)} leaf matching an output shape — "
                    f"likely an in-place update paying a copy",
                    severity="advice"))
                break
    return findings


# ----------------------------------------------------------------- serve runs
def _tiny_setup():
    from repro.configs.base import AttnConfig, ModelConfig, RetroConfig
    from repro.models import model as M
    retro = RetroConfig(avg_cluster=8, cluster_cap=64, prefill_segment=64,
                        update_segment=32, sink=4, local=32,
                        retrieval_frac=1.0, estimation_frac=0.0,
                        kmeans_iters=3)
    cfg = ModelConfig(
        arch_id="retrolint-tiny", family="dense", n_layers=2, d_model=64,
        d_ff=128, vocab=256,
        attn=AttnConfig(n_heads=4, n_kv_heads=2, head_dim=16),
        dtype="float32", retro=retro)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _requests(lengths: Sequence[int], max_new: int):
    from repro.serving.engine import Request
    rng = np.random.RandomState(0)
    return [Request(prompt=rng.randint(1, 250, size=(n,)).astype(np.int32),
                    max_new_tokens=max_new) for n in lengths]


@dataclass
class RunReport:
    label: str
    recorder: StageRecorder
    compiles: Counter
    expected: Dict[str, int]
    findings: List[Finding] = field(default_factory=list)


def _serve_run(label: str, cfg, params, *, lengths: Sequence[int],
               max_new: int, exercised: Sequence[str],
               n_prompt_lens: int, n_buckets: int,
               **engine_kw) -> RunReport:
    from repro.serving.engine import SERVE_STAGES, ServeEngine
    with StageRecorder() as rec, CompileLog() as clog:
        engine = ServeEngine(cfg, params, gen_headroom=256, **engine_kw)
        engine.serve(_requests(lengths, max_new), batch_size=2, seed=0)
    expected: Dict[str, int] = {}
    for name, contract in SERVE_STAGES.items():
        if name not in exercised:
            expected[name] = 0
        elif contract["budget"] == "per_prompt_len":
            expected[name] = n_prompt_lens
        elif contract["budget"] == "per_prompt_bucket":
            expected[name] = n_buckets
        else:
            expected[name] = 1
    return RunReport(label, rec, clog.counts, expected)


# run plans: which contract stages each serve mode exercises
_OFFLOAD_STAGES = ("argmax_ids", "merge_tokens", "chunk", "fin",
                   "embed_tokens", "rank_fn", "attend_fn", "unembed_logits",
                   "cache_upd", "cache_stage", "offload_flush")
_BLOCKING_STAGES = ("graft", "categorical_ids", "merge_tokens", "prefill",
                    "decode", "flush")


def run_contract_checks(verbose=None) -> List[Finding]:
    """The full trace-time gate: a chunked+offload serve and a
    blocking+direct serve (tiny config), then every SERVE_STAGES contract
    verified against what was recorded. The offload run doubles as the
    retrosched (RL301-RL305) schedule recording: a ``ScheduleRecorder``
    captures the control-plane event stream and the happens-before checker
    runs over it — no third serve run."""
    from repro.analysis.schedule_check import schedule_findings
    from repro.analysis.schedule_model import ScheduleRecorder
    from repro.serving.engine import SERVE_STAGES
    log = verbose or (lambda *_: None)
    cfg, params = _tiny_setup()
    lengths = [48, 72, 96, 72]          # ragged mix, one duplicate length

    log("retrolint: serve run 1/2 (chunked admission, host-offload decode)")
    with ScheduleRecorder() as sched:
        run_a = _serve_run(
            "chunked+offload", cfg, params, lengths=lengths, max_new=40,
            exercised=_OFFLOAD_STAGES, n_prompt_lens=len(set(lengths)),
            n_buckets=len(set(lengths)),
            admission="chunked", offload=True, temperature=0.0)
    log("retrolint: serve run 2/2 (blocking admission, direct decode)")
    run_b = _serve_run(
        "blocking+direct", cfg, params, lengths=lengths, max_new=40,
        exercised=_BLOCKING_STAGES, n_prompt_lens=len(set(lengths)),
        n_buckets=len(set(lengths)),
        admission="blocking", offload=False, temperature=0.7)

    findings: List[Finding] = []
    log("retrolint: retrosched happens-before check over the offload "
        "schedule")
    findings += schedule_findings(sched.trace)
    checked: set = set()
    for run in (run_a, run_b):
        # RL103: per-stage compile budget over the run
        for name, exp in sorted(run.expected.items()):
            obs = run.compiles.get(name, 0)
            if obs != exp:
                findings.append(Finding(
                    "RL103", ENGINE_PATH, 0, name,
                    f"stage compiled {obs}x over the {run.label} run, "
                    f"budget is {exp}"))
        # RL101/RL102/RL104 on every recorded contract stage (once per name)
        for name, rec in sorted(run.recorder.records.items()):
            contract = SERVE_STAGES.get(name)
            if contract is None or name in checked:
                continue
            if rec.avals is None:
                continue            # built but never called in this run
            checked.add(name)
            log(f"retrolint: checking stage `{name}`")
            findings += callback_findings(rec.fn, rec.avals, name)
            findings += donation_findings(
                rec.jitted, rec.avals,
                _norm_donate(rec.jit_kwargs.get("donate_argnums")),
                tuple(contract["donate"]), name)
            findings += missed_donation_findings(
                rec, tuple(contract["donate"])
                + tuple(contract.get("copy_ok", ())))
            # retronum (RL401-RL405): the stage's declared numerics
            # contract, checked over the same recorded trace
            if contract.get("numerics") is not None:
                findings += numerics_check.stage_findings(
                    rec.fn, rec.avals, name, contract["numerics"],
                    ENGINE_PATH)
    # a contract stage that NO run exercised means the registry rotted
    for name in SERVE_STAGES:
        if name not in checked and all(r.expected.get(name, 0) == 0
                                       for r in (run_a, run_b)):
            continue        # contractually idle under both plans
        if name not in checked:
            findings.append(Finding(
                "RL103", ENGINE_PATH, 0, name,
                "stage is in SERVE_STAGES but was never built by either "
                "serve run — stale contract entry or renamed stage"))
    return findings
