"""retrolint — static + trace-time contract checking for the serve hot path.

Three passes guard the invariants PRs 3-5 bought the decode loop:

* ``ast_rules``   — source-level lint (host syncs in hot-path functions,
                    Python control flow on traced values, ``jax.jit`` built
                    inside loops, reuse of donated buffers).
* ``jaxpr_check`` — trace-time contracts over the engine's jitted serve
                    stages (no callback/transfer primitives, every
                    ``donate_argnums`` entry really aliases an output, each
                    stage compiles exactly once across a mixed serve run).
* ``pallas_check`` — kernel-level analysis of the wave-attention Pallas
                    kernels (wait-before-reuse on the double-buffered DMA
                    scratch, BlockSpec index-map purity, static VMEM budget).

Run all of it with ``python -m repro.launch.lint`` (see ``--help`` /
``--explain <rule>``); rules and the pragma syntax are documented in
``README.md`` next to this file.
"""
from repro.analysis.findings import (Finding, RULES, explain_rule,
                                     load_baseline, write_baseline)

__all__ = ["Finding", "RULES", "explain_rule", "load_baseline",
           "write_baseline"]
