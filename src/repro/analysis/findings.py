"""Finding/rule plumbing shared by every retrolint pass.

A ``Finding`` is one rule violation at one source location. Its
``fingerprint`` deliberately excludes the line number — baselines must
survive unrelated edits above a suppressed site — and hashes the rule id,
repo-relative path, enclosing qualname, and a normalized message instead.

Suppression has three layers, narrowest wins:

* ``# retrolint: sync(<reason>)`` on the flagged line — sanctions exactly one
  host sync (RL001); the reason is mandatory and surfaces in ``--explain``ed
  listings, so every sanctioned sync documents itself.
* ``# retrolint: ignore(RLxxx: <reason>)`` on the flagged line — suppresses
  the named rule at that site.
* the checked-in baseline file — fingerprints of known findings; the CLI
  fails only on findings NOT in the baseline, so adopting a new rule never
  blocks on legacy sites.
"""
from __future__ import annotations

import hashlib
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional

PRAGMA_RE = re.compile(r"#\s*retrolint:\s*(sync|ignore|hot)\s*(?:\(([^)]*)\))?")


@dataclass(frozen=True)
class Rule:
    rule_id: str
    title: str
    summary: str                # one line, shown in listings
    explain: str                # long form, shown by --explain


@dataclass
class Finding:
    rule: str
    path: str                   # repo-relative, "/" separators
    line: int
    qualname: str               # enclosing def/class chain (or stage name)
    message: str
    severity: str = "error"     # "error" fails the gate; "advice" never does

    @property
    def fingerprint(self) -> str:
        norm = re.sub(r"\d+", "#", self.message)    # shape/count agnostic
        h = hashlib.sha1(
            f"{self.rule}|{self.path}|{self.qualname}|{norm}".encode()
        ).hexdigest()[:12]
        return f"{self.rule}:{self.path}:{self.qualname}:{h}"

    def render(self) -> str:
        sev = "" if self.severity == "error" else f" [{self.severity}]"
        return (f"{self.path}:{self.line}: {self.rule}{sev} "
                f"({self.qualname}) {self.message}")


# --------------------------------------------------------------------- rules
RULES: Dict[str, Rule] = {}


def _rule(rule_id: str, title: str, summary: str, explain: str) -> None:
    RULES[rule_id] = Rule(rule_id, title, summary, explain)


_rule(
    "RL001", "host-sync-in-hot-path",
    "Host-sync call inside a decode hot-path function without a sync pragma.",
    """Functions on the decode hot path (listed in ast_rules.HOT_PATHS, or
tagged `# retrolint: hot` on their def line) may not call np.asarray /
np.array on device values, jax.device_get, .item(), or
block_until_ready(): each one blocks the Python scheduler on the device
stream and silently serializes the sync-free decode loop (PR 3) or the
offload control plane (PR 5). The engine keeps exactly one sanctioned sync
per concern; each is annotated in place:

    ids = np.asarray(idx_r)  # retrolint: sync(per-layer ids readback)

Fix: keep the value on device (sample on device, feed device-to-device), or
move the transfer off the per-step path. If the sync is load-bearing,
annotate it with `# retrolint: sync(<why this one is allowed>)`.""")

_rule(
    "RL002", "traced-python-control-flow",
    "Python if/for/while on a traced value inside a jitted function.",
    """Inside a function compiled with jax.jit, Python `if`, `while`, and
`for` execute at TRACE time. Branching on a traced value either raises a
ConcretizationTypeError or — worse — silently bakes one branch into the
compiled artifact and recompiles per value. Use lax.cond / lax.select /
jnp.where for data-dependent branches and lax.fori_loop / lax.scan for
data-dependent trip counts. Static configuration (None checks, shapes,
dtypes, static_argnames) is fine and not flagged.

The pass is lexical: it only inspects functions it can SEE are jitted
(decorated with @jax.jit / @partial(jax.jit, ...) or wrapped by name in the
same scope) and tracks taint from their non-static parameters.""")

_rule(
    "RL003", "jit-inside-loop",
    "jax.jit(...) constructed inside a Python loop body.",
    """Each jax.jit(...) call creates a fresh compilation cache: building one
inside a `for`/`while` body recompiles every iteration and leaks executables.
Hoist the jit out of the loop (module scope, or a cached builder keyed on the
static geometry — see ServeEngine._decode_fns for the idiom).""")

_rule(
    "RL004", "reuse-after-donation",
    "A value passed at a donated argument position is read again later.",
    """Arguments listed in donate_argnums are INVALIDATED by the call: the
buffer is aliased into the outputs and reading the old reference afterwards
raises (or, pre-deletion, observes clobbered memory). The flagged name was
passed at a donated position and is loaded again after the call (or on the
next loop iteration) without being rebound. Rebind the name from the call's
result (`state = fin(state, ...)`) or drop the donation.""")

_rule(
    "RL101", "callback-primitive-in-stage",
    "A jitted serve stage traces a callback / host-transfer primitive.",
    """The decode-loop contract is that every jitted stage is pure device
compute: host work happens only at the annotated control-plane points
between stages. A pure_callback / io_callback / debug_callback / device_put
primitive inside a stage jaxpr reintroduces a hidden per-step host
round-trip that no wall-clock test reliably catches. Move the host work to
the control plane (see _OffloadPlane.decode_step) or delete it.""")

_rule(
    "RL102", "donation-not-aliased",
    "A donate_argnums entry does not alias any output (silent copy), or a "
    "stage is missing its contracted donation.",
    """jax only honours donate_argnums when an output with matching
shape/dtype exists; otherwise the donation silently degrades to a full
copy (XLA emits a UserWarning once, then the copy runs forever). The
checker lowers every recorded serve stage and requires each donated leaf to
carry a tf.aliasing_output attribute. It also enforces the per-stage
donation contract (serving.engine.SERVE_STAGES): a stage that updates a
large buffer in place must declare the donation, or every step pays a
defensive copy of the whole buffer.""")

_rule(
    "RL103", "recompile-budget-exceeded",
    "A jitted serve stage compiled more (or less) often than its budget.",
    """Across a mixed serve run every stage compiles a fixed number of times:
once per engine geometry for the step stages, once per distinct prompt
length for the finalize/prefill entries. More compiles means a shape or
static argument leaks per-step state into the jit key (the classic
regression: a Python scalar that should be a device array); zero compiles
means the stage was renamed or silently bypassed and the contract no longer
measures it.""")

_rule(
    "RL104", "missed-donation",
    "An un-donated stage input has an identically-shaped output (advice).",
    """Heuristic, advisory only: the stage returns a value with exactly the
shape/dtype of a large un-donated input, which usually means an in-place
update paying a full defensive copy. Donate the argument if the caller
never reuses the old reference (then add it to SERVE_STAGES so RL102
enforces it); ignore if the output is genuinely fresh data.""")

_rule(
    "RL201", "dma-wait-before-reuse",
    "Double-buffered DMA scratch read/overwritten without an awaited copy.",
    """The paged kernel's cluster walk streams cluster j+1's blocks into one
half of a 2-slot VMEM scratch while folding cluster j from the other half.
That is only sound if (a) every scratch read is preceded by a wait() on the
same slot's semaphore, (b) no DMA is started into a slot whose previous
transfer has not been awaited, and (c) no DMA overwrites a slot whose
contents have not been folded yet. The checker extracts the start/wait/read
event sequence from the kernel AST (inlining the dma helper and the
fori_loop body) and model-checks the slot state machine over unrolled
iterations. A violated ordering is a silent data race on real hardware —
interpret-mode tests cannot see it because the interpreter serializes
DMAs.""")

_rule(
    "RL202", "impure-blockspec-index-map",
    "BlockSpec index map does something other than pure index arithmetic.",
    """BlockSpec index maps run at every grid step to pick the next block;
Pallas assumes they are pure functions of the grid indices (plus
scalar-prefetch refs). Side effects, captured mutable state, or calls
outside simple index arithmetic (jnp.clip and friends) make the automatic
pipeline's prefetch order undefined. Keep maps to arithmetic on the grid
indices and subscripts of scalar-prefetch ref parameters.""")

_rule(
    "RL203", "vmem-budget-exceeded",
    "Static VMEM footprint estimate exceeds the configured budget.",
    """Sums every pltpu.VMEM scratch allocation plus 2x (pipeline double
buffering) each BlockSpec block in the kernel builders, with symbolic dims
resolved from the geometry env (see --geometry). The estimate is a
conservative upper bound (both cluster-walk flavors counted); exceeding the
budget means the kernel will spill or fail to fit at that geometry — shrink
block_l / cluster_cap or re-tile before it reaches hardware.""")


_rule(
    "RL301", "staging-read-before-miss-write",
    "Attend reads the miss staging tail before this step's staging write "
    "landed (or the staging write consumed miss payloads not yet built).",
    """The offload decode step stages this step's cache misses into the tail
slots [C, C+r) of the device block cache, then attends over them. In the
happens-before model of the recorded schedule, every ``attend_fn`` that
reads ``cache_tail[l]`` must be preceded (device-stream order, same step)
by the ``cache_stage``/``cache_upd`` write that staged this step's misses,
and that dispatch must itself follow the host-side ``translate`` that built
the miss payloads. A schedule that dispatches the attend first reads stale
tail payloads from the PREVIOUS step — silently wrong attention that is
bit-plausible (the tail always holds *some* well-formed cluster).""")

_rule(
    "RL302", "stale-mapping-table",
    "Translation consulted after a slot-remapping apply_updates whose "
    "device-cache mirror has not landed (stale ClusterMappingTable).",
    """``apply_updates`` (the deferred-admission drain) remaps
ClusterMappingTable entries to device-cache slots and queues the payload
mirror; the mirror is scattered into the device cache by the NEXT step's
``cache_upd``. A ``translate`` that runs after the drain hands out the NEW
slot ids, so the attend consuming them must be preceded by a ``cache_upd``
that consumed the admission queue — otherwise the kernel reads whatever the
evicted cluster left in those slots. The checker requires, for every drain
that wrote the admission queue, a queue-consuming ``cache_upd`` dispatch
between the drain and the next attend on that layer.""")

_rule(
    "RL303", "mirror-overwrites-inflight-slot",
    "A host-space write lands in a device cache buffer racing an in-flight "
    "attend (no sync or stream edge orders them).",
    """Device-side writes to the block cache are safe because the single
device stream serializes them against the attends that read the same
buffers. A write that does NOT ride the stream — a host-side scatter into
the mirror, a transfer on a second stream — races any attend that was
dispatched but not yet proven complete (no host sync on a later stream
value). The model checker flags host-space writes to device buffers with an
in-flight reader and no ordering edge. Keep mirror updates in jitted
stages (``cache_upd``) so the stream orders them.""")

_rule(
    "RL304", "pipeline-opportunity",
    "A host sync blocks with an idle host while independent host work "
    "exists that could overlap it (advice).",
    """The pipeline-opportunity detector. For every blocking readback the
checker looks at the host-order gap between the producing dispatch and the
sync: if the host did nothing in that gap, and a host-side op with real
effects sits immediately before the producer with NO dependency path into
it, that op could legally run inside the gap — the sync would then overlap
host work instead of idling. This is the finding that motivated the
layer-pipelined offload decode schedule: dispatch layer l+1's rank (and
start its id readback) BEFORE draining layer l's deferred admissions, so
the per-layer id sync overlaps the drain and the device's attend.""")

_rule(
    "RL305", "donation-reuse-across-overlap",
    "A donated buffer is read or re-donated by a later op without being "
    "rebound in between.",
    """Donating a buffer to a dispatched stage invalidates the host's
reference: once stages from different layers overlap, passing the dead
reference to a later dispatch (or reading it from host code) observes
clobbered memory on hardware even when the interpreter happens to keep it
alive. In the happens-before model every donated buffer must be rebound —
written, or passed through as an aliased output — before any later event
reads or re-donates it. The AST rule RL004 catches the lexical version of
this; RL305 checks the actual recorded schedule, where the reuse can span
stages that no single function body shows.""")

_rule(
    "RL401", "sub-f32-softmax-chain",
    "A softmax/exp/log/LSE-chain transcendental computes on a sub-f32 "
    "float operand.",
    """The accuracy-bounded estimation math (paper Sec. 4.4) hinges on the
softmax/log-sum-exp chain being computed in f32: the online-softmax fold's
running max/normalizer, the estimation zone's `cs + log(sz)` Jensen logits
and the retrieval-cover entries all feed `exp`/`log` whose bf16 evaluation
loses ~5 bits of mantissa exactly where the attention weights are decided.
retronum walks every stage jaxpr (and the Pallas kernel body) and flags any
`exp`/`log`/`log1p`/`expm1`/`logistic`/`tanh`/`exp2`/`log2` primitive whose
float operand is narrower than the stage's declared softmax floor
(`numerics["softmax"]`, f32 everywhere today). Fix: upcast the *operand
row* (`x.astype(jnp.float32)`) — small, per-tile — never store the chain in
bf16.""")

_rule(
    "RL402", "dot-accumulation-contract",
    "A dot/einsum violates the storage-dtype-operand + "
    "preferred_element_type=f32 accumulation contract.",
    """Two ways to get mixed-precision matmuls wrong, both flagged here:
(a) a `dot_general` with sub-f32 operands and no
`preferred_element_type=jnp.float32` accumulates in bf16 (jax defaults the
accumulator to the operand dtype); (b) the hoisted-cast hazard — an
explicit `astype(jnp.float32)` on a large stored operand *before* the dot.
XLA hoists the convert through the gather/slice that follows it, so the
ENTIRE store is converted and written back at 2x the bytes every decode
step (the documented idiom at `core/attention.py` §Perf). The contract:
keep operands in storage dtype, pass `preferred_element_type=jnp.float32`,
and let the MXU/kernel widen per tile in registers/VMEM. retronum flags
(a) structurally and (b) by provenance: a widening convert of >= 4 MiB
feeding a dot operand outside a Pallas kernel body.""")

_rule(
    "RL403", "double-rounding",
    "A value is round-tripped f32 -> sub-f32 -> f32 before accumulation "
    "(two roundings where the contract allows one).",
    """Narrowing to bf16 and immediately widening back to f32 silently
rounds the value twice: once at the narrowing (drops 16 mantissa bits) and
once wherever the widened value is consumed against other rounded values.
The numerics contract allows exactly ONE narrowing per value — either the
sanctioned output downcast (RL404) or a storage write that a later stage
widens ON READ via the dot contract (RL402). A convert chain
`f32 -> bf16 -> f32` inside one stage is never that: it is usually a
leftover `astype` pair from refactoring, and it turns the error bound of
the fold from one-rounding to two. retronum detects the widening convert
whose producer is a narrowing convert from an equal-or-wider dtype.""")

_rule(
    "RL404", "unsanctioned-downcast",
    "A narrowing cast is consumed by general compute — the only sanctioned "
    "narrowings are the stage output and same-dtype storage writes.",
    """Per-stage, the numerics contract sanctions exactly two narrowings
(`numerics["narrow"] == "output-only"`): the final `astype(q.dtype)` on the
stage OUTPUT (values leave the f32 accumulation domain once, at the end),
and a cast that feeds a same-dtype STORAGE write (scatter /
dynamic_update_slice into a bf16 store, e.g. `dense_cache_append`) or a
dot_general that re-widens via `preferred_element_type=f32` (the
`p.astype(v.dtype)` probability-operand idiom). Any other consumer of a
narrowed value — adds, muls, reductions, transcendentals — means part of
the fold now runs in bf16 mid-stage, which is invisible to parity tests at
small sizes and exactly the regression the paper's accuracy claim cannot
absorb. Fix: move the narrowing to the stage boundary, or drop it.""")

_rule(
    "RL405", "lse-merge-dtype-mismatch",
    "The LSE-merge path (return_parts / distributed psum) carries a "
    "sub-f32 partial accumulator or collective.",
    """`wave_attention_attend(..., return_parts=True)` returns the raw
(num, den, m) flash partials so shards (`core/distributed.py`) — and the
roadmap's CPU/GPU co-execution split — can merge attentions computed over
disjoint cluster sets: `m_glob = pmax(m)`, rescale by `exp(m - m_glob)`,
`psum` numerator and denominator, divide once. The merge is only exact if
every partial stays f32 until the single final downcast: a bf16 `den`
loses the low bits that distinguish near-tied shards, and a collective
over bf16 partials rounds once PER SHARD. retronum checks the parts
triple's dtypes at the trace boundary and flags any
psum/pmax/pmin collective whose float operand is sub-f32.""")

_rule(
    "RL406", "cast-site-inventory",
    "Certified VMEM-stage cast-site inventory for the paged kernel "
    "(advice).",
    """Not a defect — the certified list of every per-block widening cast
inside the paged wave-attention kernel bodies (`kernel.py`, both
double_buffer flavors, traced through `ops.paged_wave_attention`'s
kernel-inlining path). These VMEM-stage casts are exactly where the
roadmap's quantized payload store will hook per-cluster dequantization
(int8/fp8 row -> scale -> f32 tile), so the inventory doubles as the
integration-point contract for that PR: a cast site disappearing or a new
un-inventoried cast appearing shows up as a diff in this advice list (and
in the `--json-out` artifact CI uploads). Each entry records the source
site, src/dst dtypes and the block shape being widened.""")


def explain_rule(rule_id: str) -> Optional[str]:
    r = RULES.get(rule_id)
    if r is None:
        return None
    return f"{r.rule_id} — {r.title}\n\n{r.summary}\n\n{r.explain}\n"


# ------------------------------------------------------------------ pragmas
@dataclass
class Pragmas:
    """Per-file pragma index: line -> (kind, payload)."""
    by_line: Dict[int, List] = field(default_factory=dict)

    @classmethod
    def scan(cls, source: str) -> "Pragmas":
        p = cls()
        for i, text in enumerate(source.splitlines(), start=1):
            for m in PRAGMA_RE.finditer(text):
                p.by_line.setdefault(i, []).append(
                    (m.group(1), (m.group(2) or "").strip()))
        return p

    def sanctions_sync(self, line: int) -> bool:
        return any(k == "sync" and payload
                   for k, payload in self.by_line.get(line, []))

    def ignores(self, line: int, rule_id: str) -> bool:
        return any(k == "ignore" and rule_id in payload
                   for k, payload in self.by_line.get(line, []))

    def marks_hot(self, line: int) -> bool:
        return any(k == "hot" for k, _ in self.by_line.get(line, []))


# ----------------------------------------------------------------- baseline
def load_baseline(path: str) -> set:
    try:
        with open(path) as f:
            return {ln.strip() for ln in f
                    if ln.strip() and not ln.lstrip().startswith("#")}
    except FileNotFoundError:
        return set()


def write_baseline(path: str, findings: List[Finding]) -> None:
    with open(path, "w") as f:
        f.write("# retrolint suppression baseline — one fingerprint per "
                "line.\n# Regenerate with: python -m repro.launch.lint "
                "--write-baseline\n")
        for fp in sorted({x.fingerprint for x in findings
                          if x.severity == "error"}):
            f.write(fp + "\n")


def apply_baseline(findings: List[Finding], baseline: set) -> List[Finding]:
    """Errors whose fingerprint is baselined are dropped; advice passes
    through untouched (it never gates)."""
    return [f for f in findings
            if f.severity != "error" or f.fingerprint not in baseline]
