"""retrolint self-tests: every rule against a known-good and a known-bad
fixture.

The bad fixtures double as the CI tripwire: each is a complete source
snippet that, if seeded into ``src/``, MUST make ``repro.launch.lint`` exit
non-zero (the good twin must stay silent). ``run_selftests()`` executes the
whole table and returns the failures; the CLI (``--selftest``) and
``tests/test_analysis.py`` both consume it.

AST/Pallas fixtures run through the real source-level drivers. The jaxpr
rules (RL101/RL102) are exercised with real traced functions — tiny jits
with a deliberately smuggled callback / un-aliasable donation.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List

from repro.analysis.ast_rules import lint_source
from repro.analysis.findings import Finding
from repro.analysis.pallas_check import check_source

# --------------------------------------------------------------- AST fixtures
_RL001_BAD = '''
import numpy as np

def decode_step(state):  # retrolint: hot
    ids = np.asarray(state.idx)           # unsanctioned host sync
    return ids
'''

_RL001_GOOD = '''
import numpy as np

def decode_step(state):  # retrolint: hot
    ids = np.asarray(state.idx)  # retrolint: sync(control-plane readback)
    return ids

def cold_path(state):
    return np.asarray(state.idx)          # not a hot function: fine
'''

_RL002_BAD = '''
import jax

@jax.jit
def f(x):
    if x > 0:                             # traced-value branch
        return x
    return -x
'''

_RL002_GOOD = '''
import jax
import jax.numpy as jnp

@jax.jit
def f(x, flag=None):
    if flag is None:                      # static identity check: fine
        x = x + 1
    for i in range(x.shape[0]):           # shape is static: fine
        x = x + i
    return jnp.where(x > 0, x, -x)        # data-dependent: on device
'''

_RL003_BAD = '''
import jax

def build(fns):
    out = []
    for f in fns:
        out.append(jax.jit(f))            # fresh jit cache per iteration
    return out
'''

_RL003_GOOD = '''
import jax

def build(fns):
    jitted = [jax.jit(f) for f in fns]    # comprehension builder: cached once

    def runner(xs):
        for f, x in zip(jitted, xs):      # calling in a loop is fine
            f(x)
    return runner
'''

_RL004_BAD = '''
import jax
from functools import partial

@partial(jax.jit, donate_argnums=(0,))
def step(state, x):
    return state

def loop(state, xs):
    for x in xs:
        out = step(state, x)              # state re-donated every iteration
    return out
'''

_RL004_GOOD = '''
import jax
from functools import partial

@partial(jax.jit, donate_argnums=(0,))
def step(state, x):
    return state

def loop(state, xs):
    for x in xs:
        state = step(state, x)            # rebound from the result
    return state
'''

# ------------------------------------------------------------ Pallas fixtures
_RL201_GOOD = '''
import jax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

def _db_kernel(idx_ref, kst_ref, kdb_scr, ksem, o_ref, *, r):
    def dmas(slot, jc):
        cid = idx_ref[0, jc]
        return (pltpu.make_async_copy(kst_ref.at[0, cid], kdb_scr.at[slot],
                                      ksem.at[slot]),)

    for c in dmas(0, 0):                  # warm up slot 0
        c.start()

    def body(jc, carry):
        cur = jax.lax.rem(jc, 2)
        nxt = jax.lax.rem(jc + 1, 2)

        @pl.when(jc + 1 < r)
        def _prefetch():
            for c in dmas(nxt, jc + 1):   # prefetch next into OTHER slot
                c.start()

        for c in dmas(cur, jc):           # await current before reading
            c.wait()
        o_ref[0] = kdb_scr[cur]
        return carry

    jax.lax.fori_loop(0, r, body, 0)
'''

# read without ever waiting: the headline silent data race
_RL201_BAD_NOWAIT = _RL201_GOOD.replace(
    """        for c in dmas(cur, jc):           # await current before reading
            c.wait()
""", "")

# prefetch into the slot currently being folded
_RL201_BAD_SAME_SLOT = _RL201_GOOD.replace("dmas(nxt, jc + 1)",
                                           "dmas(cur, jc + 1)")

# warm-up removed: first wait has nothing in flight
_RL201_BAD_NO_WARMUP = _RL201_GOOD.replace(
    """    for c in dmas(0, 0):                  # warm up slot 0
        c.start()
""", "")

_RL202_BAD = '''
from jax.experimental import pallas as pl

def build(x, table):
    bad = lambda b, j: (b, table.lookup(j), 0)    # arbitrary call: impure
    return pl.BlockSpec((1, 8, 128), bad)
'''

_RL202_GOOD = '''
import jax.numpy as jnp
from jax.experimental import pallas as pl

def build(nlb, r):
    lmap = lambda b, j, *_: (b, jnp.clip(j - 1, 0, nlb - 1), 0)
    cmap = lambda b, j, idx_ref, *_: (b, idx_ref[b, j], 0, 0)
    return pl.BlockSpec((1, 8, 128), lmap), pl.BlockSpec((1, 1, 64), cmap)
'''

_RL203_BAD = '''
import jax.numpy as jnp
from jax.experimental.pallas import tpu as pltpu

def build_kernel():
    return [pltpu.VMEM((4096, 4096, 4), jnp.float32)]   # 256 MiB scratch
'''

_RL203_GOOD = '''
import jax.numpy as jnp
from jax.experimental.pallas import tpu as pltpu

def build_kernel(cap, hd):
    return [pltpu.VMEM((2, cap, hd), jnp.float32)]
'''


@dataclass
class Fixture:
    rule: str
    bad: str
    good: str
    checker: Callable[[str], List[Finding]]


def _ast(src: str) -> List[Finding]:
    return lint_source(src, "selftest.py")


def _pallas(src: str) -> List[Finding]:
    return check_source(src, "selftest.py")


FIXTURES: List[Fixture] = [
    Fixture("RL001", _RL001_BAD, _RL001_GOOD, _ast),
    Fixture("RL002", _RL002_BAD, _RL002_GOOD, _ast),
    Fixture("RL003", _RL003_BAD, _RL003_GOOD, _ast),
    Fixture("RL004", _RL004_BAD, _RL004_GOOD, _ast),
    Fixture("RL201", _RL201_BAD_NOWAIT, _RL201_GOOD, _pallas),
    Fixture("RL201", _RL201_BAD_SAME_SLOT, _RL201_GOOD, _pallas),
    Fixture("RL201", _RL201_BAD_NO_WARMUP, _RL201_GOOD, _pallas),
    Fixture("RL202", _RL202_BAD, _RL202_GOOD, _pallas),
    Fixture("RL203", _RL203_BAD, _RL203_GOOD, _pallas),
]

# bad fixtures by rule, exported so tests can seed them into a fake src/
# tree and assert the CLI gate trips
BAD_FIXTURES: Dict[str, str] = {}
for _fx in FIXTURES:
    BAD_FIXTURES.setdefault(_fx.rule, _fx.bad)


# -------------------------------------------------- traced-rule self-tests
def _selftest_rl101() -> List[str]:
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.analysis.jaxpr_check import callback_findings
    aval = (jax.ShapeDtypeStruct((8,), jnp.float32),)

    def bad(x):
        return jax.pure_callback(
            np.sin, jax.ShapeDtypeStruct(x.shape, x.dtype), x)

    def good(x):
        return jnp.sin(x)

    fails = []
    if not any(f.rule == "RL101" for f in callback_findings(bad, aval, "bad")):
        fails.append("RL101: callback stage not flagged")
    if callback_findings(good, aval, "good"):
        fails.append("RL101: pure stage falsely flagged")
    return fails


def _selftest_rl102() -> List[str]:
    import jax
    import jax.numpy as jnp
    from repro.analysis.jaxpr_check import donation_findings
    aval = (jax.ShapeDtypeStruct((128,), jnp.float32),)

    def update(x):
        return x + 1.0                      # same shape: donation aliases

    def reduce(x):
        return jnp.sum(x)                   # no matching output: silent copy

    good = jax.jit(update, donate_argnums=(0,))
    bad = jax.jit(reduce, donate_argnums=(0,))
    fails = []
    if donation_findings(good, aval, (0,), (0,), "good"):
        fails.append("RL102: aliasing donation falsely flagged")
    if not any(f.rule == "RL102"
               for f in donation_findings(bad, aval, (0,), (0,), "bad")):
        fails.append("RL102: non-aliasing donation not flagged")
    if not any(f.rule == "RL102"
               for f in donation_findings(good, aval, (), (0,), "missing")):
        fails.append("RL102: missing contracted donation not flagged")
    return fails


def _selftest_rl103() -> List[str]:
    import jax
    import jax.numpy as jnp
    from repro.analysis.jaxpr_check import CompileLog

    def shapely_stage(x):
        return x * 2.0

    jitted = jax.jit(shapely_stage)
    with CompileLog() as clog:
        jitted(jnp.zeros((4,), jnp.float32))
        jitted(jnp.zeros((4,), jnp.float32))    # cache hit: no recompile
        jitted(jnp.zeros((8,), jnp.float32))    # new shape: recompile
    n = clog.counts.get("shapely_stage", 0)
    if n != 2:
        return [f"RL103: compile log counted {n} compiles, expected 2"]
    return []


# ---------------------------------------------- retrosched (RL3xx) fixtures
# Schedule fixtures are op sequences resolved through the REAL SERVE_STAGES
# effects declarations (schedule_model.build_trace), so each selftest
# exercises exactly the model the live engine trace is held to. Ops outside
# the table (a rogue host mirror, a donation with no rebind) inject raw
# effects via the extras channel.
def _sched_check(schedule, rule: str, expect: bool, label: str) -> List[str]:
    from repro.analysis.schedule_check import check_trace
    from repro.analysis.schedule_model import build_trace
    hits = [f for f in check_trace(build_trace(schedule, 2))
            if f.rule == rule]
    if expect and not hits:
        return [f"{rule}: {label} schedule not flagged"]
    if not expect and hits:
        return [f"{rule}: {label} schedule falsely flagged: "
                f"{hits[0].render()}"]
    return []


def _selftest_rl301() -> List[str]:
    from repro.analysis.schedule_check import reference_schedule
    # attend dispatched BEFORE the staging write: move each layer's
    # cache-stage dispatch to just after its attend
    bad: List[tuple] = []
    held = None
    for ev in reference_schedule():
        if ev[2] in ("cache_stage", "cache_upd"):
            held = ev
            continue
        bad.append(ev)
        if ev[2] == "attend_fn" and held is not None:
            bad.append(held)
            held = None
    fails = _sched_check(bad, "RL301", True, "attend-before-staging-write")
    fails += _sched_check(reference_schedule(), "RL301", False,
                          "pipelined reference")
    return fails


def _selftest_rl302() -> List[str]:
    from repro.analysis.schedule_check import reference_schedule
    # admissions queued by the drain but the next step stages with
    # cache_stage — the mapping table got remapped without its mirror edge
    fails = _sched_check(reference_schedule(drop_mirror=True), "RL302",
                         True, "mirror-dropping")
    fails += _sched_check(reference_schedule(), "RL302", False,
                          "pipelined reference")
    return fails


def _selftest_rl303() -> List[str]:
    from repro.analysis.schedule_check import reference_schedule
    mirror = {"effects": {"writes": ("cache_body[l]",)}}
    logits_sync = {"effects": {"reads": ("logits",)}}

    def with_host_mirror(synced: bool):
        # the mirror targets layer 1: layer 0's attend is already proven
        # complete by layer 1's id sync, so only the last attend is in flight
        sched = list(reference_schedule(steps=1))
        tail = [(0, 1, "host_mirror", "host", mirror)]
        if synced:       # sync on the logits first: attend proven complete
            tail.insert(0, (0, -1, "sample_sync", "sync", logits_sync))
        return sched + tail

    fails = _sched_check(with_host_mirror(False), "RL303", True,
                         "unsynced host mirror")
    fails += _sched_check(with_host_mirror(True), "RL303", False,
                          "synced host mirror")
    return fails


def _selftest_rl304() -> List[str]:
    from repro.analysis.schedule_check import reference_schedule
    # the pre-pipeline engine order: drain(l) runs BEFORE rank(l+1) is
    # dispatched, so the id sync idles behind independent host work
    fails = _sched_check(reference_schedule(pipelined=False), "RL304",
                         True, "unpipelined")
    fails += _sched_check(reference_schedule(), "RL304", False,
                          "pipelined reference")
    return fails


def _selftest_rl305() -> List[str]:
    from repro.analysis.schedule_check import reference_schedule
    # rank donates the live tree but (unlike the real stage) does not return
    # a rebound copy — the later attend reads clobbered memory
    leaky = {"effects": {"reads": ("hidden", "live[l]"),
                         "writes": ("ctx[l]", "ids[l]"),
                         "donates": ("live[l]",)}}
    bad = [ev if ev[2] != "rank_fn" else ev[:4] + (leaky,)
           for ev in reference_schedule(steps=1)]
    fails = _sched_check(bad, "RL305", True, "donation-without-rebind")
    fails += _sched_check(reference_schedule(), "RL305", False,
                          "pipelined reference")
    return fails


# ------------------------------------------------------- retronum (RL4xx)
def _num_check(fn, avals, rule: str, want_bad: bool, label: str,
               contract=None) -> List[str]:
    """Trace ``fn`` through the retronum pass; assert the rule fires (bad
    twin) or that NO error fires at all (good twin)."""
    from repro.analysis.numerics_check import numerics_findings
    fs = numerics_findings(fn, avals, label,
                           path="src/repro/analysis/selftest.py",
                           contract=contract)
    errs = [f for f in fs if f.severity == "error"]
    if want_bad:
        if not any(f.rule == rule for f in errs):
            return [f"{rule}: {label} not flagged"]
        return []
    if errs:
        return [f"{rule}: {label} falsely flagged: {errs[0].render()}"]
    return []


def _selftest_rl401() -> List[str]:
    import jax
    import jax.numpy as jnp
    aval = (jax.ShapeDtypeStruct((8, 16), jnp.bfloat16),)
    # a bf16 LSE chain: exp runs on the storage dtype
    fails = _num_check(lambda x: jax.nn.softmax(x, axis=-1), aval,
                       "RL401", True, "bf16 softmax chain")
    fails += _num_check(
        lambda x: jax.nn.softmax(x.astype(jnp.float32), axis=-1), aval,
        "RL401", False, "f32-upcast softmax chain")
    return fails


def _selftest_rl402() -> List[str]:
    import jax
    import jax.numpy as jnp
    a = jax.ShapeDtypeStruct((2048, 2048), jnp.bfloat16)   # 8 MiB "store"
    b = jax.ShapeDtypeStruct((2048, 64), jnp.bfloat16)
    # (a) sub-f32 operands, accumulator defaults to bf16
    fails = _num_check(
        lambda x, y: jnp.einsum("ij,jk->ik", x, y), (a, b),
        "RL402", True, "einsum without preferred_element_type")
    # (b) the hoisted-cast hazard: whole-store astype(f32) before the dot
    fails += _num_check(
        lambda x, y: jnp.einsum("ij,jk->ik", x.astype(jnp.float32),
                                y.astype(jnp.float32)), (a, b),
        "RL402", True, "explicit whole-store pre-upcast")
    fails += _num_check(
        lambda x, y: jnp.einsum("ij,jk->ik", x, y,
                                preferred_element_type=jnp.float32), (a, b),
        "RL402", False, "storage operands + preferred_element_type")
    return fails


def _selftest_rl403() -> List[str]:
    import jax
    import jax.numpy as jnp
    aval = (jax.ShapeDtypeStruct((8, 8), jnp.float32),)
    fails = _num_check(
        lambda x: x.astype(jnp.bfloat16).astype(jnp.float32) + 1.0, aval,
        "RL403", True, "f32->bf16->f32 round trip")
    fails += _num_check(lambda x: x + 1.0, aval,
                        "RL403", False, "straight f32 chain")
    return fails


def _selftest_rl404() -> List[str]:
    import jax
    import jax.numpy as jnp
    aval = (jax.ShapeDtypeStruct((8, 8), jnp.float32),)
    # narrowed mid-stage, then general compute consumes the bf16 value
    fails = _num_check(
        lambda x: x.astype(jnp.bfloat16) * jnp.bfloat16(2.0), aval,
        "RL404", True, "mid-stage downcast consumed by compute")
    # output-only narrowing: the sanctioned final astype
    fails += _num_check(
        lambda x: (x * 2.0).astype(jnp.bfloat16), aval,
        "RL404", False, "output-only downcast")
    return fails


def _selftest_rl405() -> List[str]:
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.analysis.numerics_check import parts_findings
    f32 = jnp.float32
    avals = (jax.ShapeDtypeStruct((2, 4), f32),
             jax.ShapeDtypeStruct((2,), f32),
             jax.ShapeDtypeStruct((2,), f32))
    fails = []
    fs = parts_findings(
        lambda n, d, m: (n.astype(jnp.bfloat16), d, m), avals,
        "bf16-num", path="selftest")
    if not any(f.rule == "RL405" for f in fs):
        fails.append("RL405: bf16 LSE-merge partial not flagged")
    fs = parts_findings(lambda n, d, m: (n, d, m), avals,
                        "f32-parts", path="selftest")
    if fs:
        fails.append(f"RL405: f32 parts falsely flagged: {fs[0].render()}")
    # collective flavor: a psum over bf16 partials inside shard_map
    try:
        from jax.experimental.shard_map import shard_map
    except ImportError:                                    # pragma: no cover
        from jax import shard_map
    from jax.sharding import Mesh, PartitionSpec as P
    mesh = Mesh(np.array(jax.devices()[:1]), ("x",))

    def collective(cast):
        def body(x):
            y = x.astype(jnp.bfloat16) if cast else x
            return jax.lax.psum(y, "x")
        return shard_map(body, mesh=mesh, in_specs=P(), out_specs=P(),
                         check_rep=False)
    aval = (jax.ShapeDtypeStruct((8,), f32),)
    fails += _num_check(collective(True), aval,
                        "RL405", True, "psum over bf16 partials")
    fails += _num_check(collective(False), aval,
                        "RL405", False, "psum over f32 partials")
    return fails


def _selftest_rl406() -> List[str]:
    from repro.analysis.numerics_check import (_pallas_avals,
                                               numerics_findings)
    inventory: List = []
    fn, avals = _pallas_avals(double_buffer=True)
    fs = numerics_findings(fn, avals, "paged_wave_attention",
                           path="src/repro/kernels/wave_attention/ops.py",
                           inventory=inventory)
    fails = []
    if [f for f in fs if f.severity == "error"]:
        fails.append(f"RL406: kernel trace errored: {fs[0].render()}")
    if not inventory:
        fails.append("RL406: paged-kernel VMEM cast inventory came back "
                     "empty — the kernel-inlining path broke")
    if any(f.severity != "advice" or f.rule != "RL406" for f in inventory):
        fails.append("RL406: inventory entries must be RL406 advice")
    return fails


def run_selftests(include_traced: bool = True) -> List[str]:
    """Run every fixture; return failure descriptions (empty = all pass)."""
    fails: List[str] = []
    for i, fx in enumerate(FIXTURES):
        bad_hits = [f for f in fx.checker(fx.bad) if f.rule == fx.rule]
        if not bad_hits:
            fails.append(f"{fx.rule} (fixture {i}): bad snippet not flagged")
        good_hits = [f for f in fx.checker(fx.good)
                     if f.severity == "error"]
        if good_hits:
            fails.append(
                f"{fx.rule} (fixture {i}): good snippet flagged: "
                f"{good_hits[0].render()}")
    fails += _selftest_rl301()
    fails += _selftest_rl302()
    fails += _selftest_rl303()
    fails += _selftest_rl304()
    fails += _selftest_rl305()
    if include_traced:
        fails += _selftest_rl101()
        fails += _selftest_rl102()
        fails += _selftest_rl103()
        fails += _selftest_rl401()
        fails += _selftest_rl402()
        fails += _selftest_rl403()
        fails += _selftest_rl404()
        fails += _selftest_rl405()
        fails += _selftest_rl406()
    return fails
