"""retronum — jaxpr precision-flow checker for the decode numerics contract.

The paper's accuracy claim (full-attention-level output from
accuracy-bounded estimation, Sec. 4.4/Fig. 18) rests on a mixed-precision
discipline the code states only in comments: payload stores may be bf16,
but every softmax/LSE chain, every dot accumulator and every LSE-merge
partial is f32, values are widened *per tile* (``preferred_element_type``
/ the kernel's VMEM casts) rather than via whole-store ``astype``, and the
single sanctioned narrowing is the stage-output ``astype(q.dtype)`` (plus
same-dtype storage writes). retronum makes that discipline machine-checked:

* an abstract interpreter flattens a stage jaxpr (inlining ``pjit`` and
  friends, recursing into ``cond``/``scan``/``while``/``shard_map`` bodies
  and — with ``pallas_check``'s kernel-inlining trick — into the Pallas
  kernel body under ``pallas_call``'s ``jaxpr`` param) into a dataflow
  graph over SSA values,
* propagates a precision lattice (storage dtype x accumulation dtype x
  rounding count, tracked via convert provenance) through it,
* and checks the per-stage contract declared as ``numerics=`` in
  ``serving.engine.SERVE_STAGES`` (schema: ``README.md``).

Rules: RL401 (sub-f32 softmax/exp/log chain), RL402 (dot accumulation:
missing ``preferred_element_type=f32`` or the hoisted whole-store upcast),
RL403 (f32->bf16->f32 double rounding), RL404 (narrowing consumed by
general compute), RL405 (LSE-merge partial/collective below f32), RL406
(advice: the certified VMEM cast-site inventory the quantization roadmap
item will hook dequant into).

Two drivers: :func:`stage_findings` runs inside
``jaxpr_check.run_contract_checks`` over every *recorded* serve stage;
:func:`run_numerics_checks` traces a curated set of real decode entry
points at bf16 payload dtypes (dense fallback, jnp + fused-emulation zone
walks, the paged Pallas kernel in both ``double_buffer`` flavors, the
``return_parts``/distributed LSE-merge path) so the contract is exercised
at the dtypes production serves, not just the f32 tiny setup.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.analysis.findings import Finding

# ------------------------------------------------------------ primitive sets
# softmax/LSE-chain transcendentals (RL401). rsqrt/erf are norm/gelu
# territory with their own error budget — not part of the softmax contract.
_TRANSCENDENTAL = {"exp", "exp2", "log", "log2", "log1p", "expm1",
                   "logistic", "tanh"}
# call-like primitives inlined into the caller's graph (one flat unit)
_INLINE = {"pjit", "closed_call", "core_call", "named_call", "remat",
           "remat2", "checkpoint", "custom_jvp_call", "custom_vjp_call",
           "custom_jvp_call_jaxpr", "custom_vjp_call_jaxpr"}
# shape-only ops a value flows through unchanged (provenance walks)
_PASSTHROUGH = {"reshape", "transpose", "broadcast_in_dim", "squeeze",
                "expand_dims", "slice", "dynamic_slice", "rev", "gather",
                "concatenate", "pad", "copy", "select_n", "convert_weak",
                "stop_gradient"}
# storage writes: a narrowing feeding one of these at matching dtype is the
# sanctioned store-write path (dense_cache_append, kernel o_ref/scratch)
_STORE_WRITE = {"scatter", "scatter-add", "dynamic_update_slice", "swap",
                "masked_swap", "addupdate"}
# cross-shard collectives on the LSE-merge path (RL405)
_COLLECTIVE = {"psum", "pmax", "pmin", "all_gather", "all_to_all",
               "ppermute", "reduce_scatter"}

# RL402(b): a widening convert at least this large feeding a dot is the
# hoisted-cast hazard (XLA converts the whole store every step). Per-tile /
# query-sized upcasts stay far below it; whole payload stores sit far above.
RL402_MIN_BYTES = 4 << 20


# ------------------------------------------------------------- the contract
@dataclass(frozen=True)
class NumericsContract:
    """Per-stage numerics contract (the ``numerics=`` SERVE_STAGES field).

    softmax: dtype floor for exp/log/LSE chains            (RL401)
    accum:   dtype floor for dot_general accumulation      (RL402)
    narrow:  "output-only" — only the stage output and same-dtype storage
             writes may consume a narrowed value (RL403/RL404); "free"
             disables the narrowing rules for the stage.
    """
    softmax: str = "float32"
    accum: str = "float32"
    narrow: str = "output-only"

    @classmethod
    def from_spec(cls, spec: Optional[Dict[str, str]]) -> "NumericsContract":
        return cls() if spec is None else cls(**spec)


def _floor_bytes(name: str) -> int:
    return np.dtype(name).itemsize


# --------------------------------------------------------------- graph build
def _is_float(dtype) -> bool:
    # np.issubdtype does not know the ml_dtypes extension floats (bf16,
    # fp8) — exactly the dtypes this checker exists for; jax's lattice does.
    import jax.numpy as jnp
    from jax import dtypes as jdt
    return jdt.issubdtype(dtype, jnp.floating)


def _aval_of(atom):
    aval = getattr(atom, "aval", None)
    # pallas kernel refs: the value of interest is the carried array
    return getattr(aval, "inner_aval", aval)


def _nbytes(aval) -> int:
    try:
        return int(np.prod(aval.shape, dtype=np.int64)) * aval.dtype.itemsize
    except Exception:
        return 0


def _site(eqn, default_path: str) -> Tuple[str, int]:
    """Repo-relative (path, line) of the user frame that traced ``eqn``."""
    try:
        from jax._src import source_info_util as siu
        fr = siu.user_frame(eqn.source_info)
        if fr is not None:
            path = fr.file_name.replace("\\", "/")
            i = path.rfind("/src/repro/")
            if i >= 0:
                path = path[i + 1:]
            return path, fr.start_line
    except Exception:
        pass
    return default_path, 0


class _Op:
    __slots__ = ("prim", "ins", "outs", "eqn")

    def __init__(self, prim, ins, outs, eqn):
        self.prim, self.ins, self.outs, self.eqn = prim, ins, outs, eqn


class _Graph:
    """One analysis unit: a flattened jaxpr body as an SSA dataflow graph."""

    def __init__(self, name: str, in_kernel: bool):
        self.name = name
        self.in_kernel = in_kernel
        self.ops: List[_Op] = []
        self.aval: Dict[int, Any] = {}          # key -> ShapedArray
        self.producer: Dict[int, _Op] = {}      # key -> defining op
        self.consumers: Dict[int, List[_Op]] = {}
        self.outvars: set = set()               # unit-output keys
        self._n = 0

    def fresh(self, aval) -> int:
        self._n += 1
        self.aval[self._n] = aval
        return self._n

    def add(self, prim, ins, outs, eqn):
        op = _Op(prim, ins, outs, eqn)
        self.ops.append(op)
        for k in ins:
            self.consumers.setdefault(k, []).append(op)
        for k in outs:
            self.producer[k] = op
        return op


def _subjaxprs(params):
    """Every Jaxpr reachable from an eqn's params (mirrors jaxpr_check)."""
    import jax.core as jc
    for v in params.values():
        vs = v if isinstance(v, (tuple, list)) else (v,)
        for x in vs:
            if isinstance(x, jc.ClosedJaxpr):
                yield x.jaxpr
            elif isinstance(x, jc.Jaxpr):
                yield x


def _inline_target(eqn):
    """The single body of a call-like primitive (ClosedJaxpr or Jaxpr)."""
    for key in ("jaxpr", "call_jaxpr"):
        sub = eqn.params.get(key)
        if sub is not None:
            return sub
    return None


def _build_units(closed, name: str) -> List[_Graph]:
    """Flatten a ClosedJaxpr into analysis units: the top-level graph (with
    all call-like prims inlined) plus one unit per control-flow/kernel body,
    recursively. Pallas kernel bodies are marked ``in_kernel``."""
    import jax.core as jc
    units: List[_Graph] = []
    pending: List[Tuple[Any, str, bool]] = [(closed.jaxpr, name, False)]
    while pending:
        jaxpr, uname, in_kernel = pending.pop(0)
        g = _Graph(uname, in_kernel)
        env: Dict[Any, int] = {}

        def key_of(atom, g=g, env=env):
            if isinstance(atom, jc.Literal):
                return g.fresh(_aval_of(atom))
            if atom not in env:
                env[atom] = g.fresh(_aval_of(atom))
            return env[atom]

        def emit(jx):
            for eqn in jx.eqns:
                prim = eqn.primitive.name
                sub = _inline_target(eqn) if prim in _INLINE else None
                if sub is not None:
                    sj = sub.jaxpr if isinstance(sub, jc.ClosedJaxpr) else sub
                    for cv in sj.constvars:
                        env[cv] = g.fresh(_aval_of(cv))
                    for iv, outer in zip(sj.invars, eqn.invars):
                        env[iv] = key_of(outer)
                    emit(sj)
                    for ov, outer in zip(sj.outvars, eqn.outvars):
                        env[outer] = key_of(ov)
                    continue
                ins = [key_of(a) for a in eqn.invars]
                outs = [key_of(v) for v in eqn.outvars]
                g.add(prim, ins, outs, eqn)
                for body in _subjaxprs(eqn.params):
                    pending.append(
                        (body, f"{uname}:{prim}",
                         in_kernel or prim == "pallas_call"))

        for v in list(jaxpr.invars) + list(jaxpr.constvars):
            env[v] = g.fresh(_aval_of(v))
        emit(jaxpr)
        g.outvars = {key_of(v) for v in jaxpr.outvars}
        units.append(g)
    return units


# ------------------------------------------------------------ rule machinery
def _walk_forward(g: _Graph, key: int):
    """Terminal (op, via_outvar) consumers of ``key`` through passthroughs."""
    seen, stack, terms, hits_out = set(), [key], [], False
    while stack:
        k = stack.pop()
        if k in seen:
            continue
        seen.add(k)
        if k in g.outvars:
            hits_out = True
        for op in g.consumers.get(k, ()):
            if op.prim in _PASSTHROUGH:
                stack.extend(op.outs)
            else:
                terms.append(op)
    return terms, hits_out


def _walk_back(g: _Graph, key: int) -> Optional[_Op]:
    """Producer of ``key`` skipping passthrough ops."""
    while True:
        op = g.producer.get(key)
        if op is None:
            return None
        if op.prim in _PASSTHROUGH and op.ins:
            key = op.ins[0]
            continue
        return op


def _store_dtype(op: _Op):
    """Destination dtype of a storage-write op (ref inner or output aval)."""
    av = _aval_of(op.eqn.invars[0]) if op.eqn.invars else None
    if av is not None and getattr(av, "dtype", None) is not None:
        return av.dtype
    return None


def _check_unit(g: _Graph, contract: NumericsContract, path: str,
                findings: List[Finding],
                inventory: Optional[List[Finding]]) -> None:
    soft_floor = _floor_bytes(contract.softmax)
    accum_floor = _floor_bytes(contract.accum)
    narrow_rules = contract.narrow == "output-only"
    for op in g.ops:
        eqn = op.eqn
        # ---- RL401: transcendental on a sub-floor float operand
        if op.prim in _TRANSCENDENTAL:
            for k in op.ins:
                av = g.aval.get(k)
                if (av is not None and _is_float(av.dtype)
                        and av.dtype.itemsize < soft_floor):
                    p, ln = _site(eqn, path)
                    findings.append(Finding(
                        "RL401", p, ln, g.name,
                        f"`{op.prim}` computes on {av.dtype.name} — the "
                        f"softmax/LSE chain must run in {contract.softmax} "
                        f"(upcast the operand row, not the store)"))
        # ---- RL402(a): dot accumulating below the floor
        elif op.prim == "dot_general":
            in_dts = [g.aval[k].dtype for k in op.ins
                      if k in g.aval and _is_float(g.aval[k].dtype)]
            out_av = g.aval.get(op.outs[0]) if op.outs else None
            if (in_dts and out_av is not None and _is_float(out_av.dtype)
                    and any(d.itemsize < accum_floor for d in in_dts)
                    and out_av.dtype.itemsize < accum_floor):
                p, ln = _site(eqn, path)
                findings.append(Finding(
                    "RL402", p, ln, g.name,
                    f"dot/einsum with {'/'.join(d.name for d in in_dts)} "
                    f"operands accumulates in {out_av.dtype.name} — pass "
                    f"preferred_element_type=jnp.{contract.accum}"))
        # ---- RL405: collective over sub-f32 partials
        elif op.prim in _COLLECTIVE:
            for k in op.ins:
                av = g.aval.get(k)
                if (av is not None and _is_float(av.dtype)
                        and av.dtype.itemsize < 4):
                    p, ln = _site(eqn, path)
                    findings.append(Finding(
                        "RL405", p, ln, g.name,
                        f"collective `{op.prim}` over {av.dtype.name} "
                        f"partials — the LSE merge rounds once per shard; "
                        f"keep (num, den, m) f32 until the final downcast"))
        elif op.prim != "convert_element_type":
            continue
        if op.prim != "convert_element_type":
            continue
        # ---------------- convert analysis (RL402b / RL403 / RL404 / RL406)
        src_av = g.aval.get(op.ins[0]) if op.ins else None
        dst_av = g.aval.get(op.outs[0]) if op.outs else None
        if (src_av is None or dst_av is None
                or not _is_float(src_av.dtype) or not _is_float(dst_av.dtype)
                or src_av.dtype == dst_av.dtype):
            continue
        widening = dst_av.dtype.itemsize > src_av.dtype.itemsize
        p, ln = _site(eqn, path)
        if g.in_kernel and inventory is not None:
            role = ("widen-to-accum (dequant hook)" if widening
                    else "output downcast")
            shape = "x".join(map(str, src_av.shape))
            inventory.append(Finding(
                "RL406", p, ln, g.name,
                f"VMEM cast site: {src_av.dtype.name}[{shape}] -> "
                f"{dst_av.dtype.name} — {role}", severity="advice"))
        if widening:
            # ---- RL403: narrow->widen round trip (two roundings)
            back = _walk_back(g, op.ins[0])
            if (back is not None and back.prim == "convert_element_type"
                    and back.ins):
                bav = g.aval.get(back.ins[0])
                if (bav is not None and _is_float(bav.dtype)
                        and bav.dtype.itemsize >= dst_av.dtype.itemsize
                        and narrow_rules):
                    findings.append(Finding(
                        "RL403", p, ln, g.name,
                        f"double rounding: value round-tripped "
                        f"{bav.dtype.name} -> {src_av.dtype.name} -> "
                        f"{dst_av.dtype.name} before accumulation"))
            # ---- RL402(b): whole-store upcast hoisted before a dot
            if (not g.in_kernel and _nbytes(src_av) >= RL402_MIN_BYTES):
                terms, _ = _walk_forward(g, op.outs[0])
                if any(t.prim == "dot_general" for t in terms):
                    findings.append(Finding(
                        "RL402", p, ln, g.name,
                        f"explicit astype({dst_av.dtype.name}) on a "
                        f"{_nbytes(src_av) >> 20} MiB {src_av.dtype.name} "
                        f"operand feeding a dot — XLA hoists the convert "
                        f"through the gather and rewrites the WHOLE store "
                        f"(2x bytes); keep storage dtype and pass "
                        f"preferred_element_type instead"))
        elif narrow_rules:
            # ---- RL404: narrowing must end at the output / a store write /
            # an f32-accumulating dot / another convert (RL403's business)
            terms, hits_out = _walk_forward(g, op.outs[0])
            bad = []
            for t in terms:
                if t.prim == "convert_element_type":
                    continue
                if t.prim in _STORE_WRITE:
                    sd = _store_dtype(t)
                    if sd is None or sd == dst_av.dtype:
                        continue
                if t.prim == "dot_general":
                    oav = g.aval.get(t.outs[0]) if t.outs else None
                    if (oav is not None
                            and oav.dtype.itemsize >= accum_floor):
                        continue
                bad.append(t.prim)
            if bad:
                findings.append(Finding(
                    "RL404", p, ln, g.name,
                    f"unsanctioned downcast {src_av.dtype.name} -> "
                    f"{dst_av.dtype.name} consumed by "
                    f"`{'`/`'.join(sorted(set(bad)))}` — only the stage "
                    f"output astype(q.dtype), same-dtype storage writes and "
                    f"f32-accumulating dots may consume a narrowed value"))
            del hits_out  # output-feeding narrows are sanctioned by absence


# ------------------------------------------------------------------ drivers
def check_closed_jaxpr(closed, *, name: str, path: str,
                       contract: Optional[NumericsContract] = None,
                       inventory: Optional[List[Finding]] = None
                       ) -> List[Finding]:
    """Run RL401-RL406 over one traced ClosedJaxpr."""
    contract = contract or NumericsContract()
    findings: List[Finding] = []
    for unit in _build_units(closed, name):
        _check_unit(unit, contract, path, findings, inventory)
    return findings


def _trace(fn, avals):
    import jax
    return jax.make_jaxpr(fn)(*avals)


def numerics_findings(fn, avals: Sequence, name: str, *, path: str,
                      contract: Optional[Dict[str, str]] = None,
                      inventory: Optional[List[Finding]] = None
                      ) -> List[Finding]:
    """Trace ``fn`` at ``avals`` and check the numerics contract."""
    try:
        closed = _trace(fn, avals)
    except Exception as e:  # a target that stops tracing breaks the gate
        return [Finding("RL401", path, 0, name,
                        f"target could not be traced for the numerics "
                        f"pass: {e!r}")]
    return check_closed_jaxpr(
        closed, name=name, path=path,
        contract=NumericsContract.from_spec(contract), inventory=inventory)


def stage_findings(fn, avals: Sequence, name: str, spec: Dict[str, str],
                   path: str) -> List[Finding]:
    """The per-recorded-stage hook ``jaxpr_check.run_contract_checks``
    calls for every SERVE_STAGES entry that declares ``numerics=``. The
    kernel cast inventory is NOT collected here (it belongs to the curated
    kernel traces in :func:`run_numerics_checks`)."""
    return numerics_findings(fn, avals, name, path=path, contract=spec,
                             inventory=None)


def parts_findings(fn, avals: Sequence, name: str, *, path: str
                   ) -> List[Finding]:
    """RL405 boundary check: the (num, den, m) LSE-merge partials a
    ``return_parts`` trace yields must all be f32."""
    try:
        closed = _trace(fn, avals)
    except Exception as e:
        return [Finding("RL405", path, 0, name,
                        f"parts target could not be traced: {e!r}")]
    findings = []
    labels = ("num", "den", "m")
    for label, v in zip(labels, closed.jaxpr.outvars):
        av = _aval_of(v)
        if (av is not None and _is_float(av.dtype)
                and av.dtype.itemsize < 4):
            findings.append(Finding(
                "RL405", path, 0, name,
                f"LSE-merge partial `{label}` leaves the stage as "
                f"{av.dtype.name} — partial accumulators must stay f32 "
                f"until the cross-shard merge's single downcast"))
    return findings


# --------------------------------------------------- the curated repo gate
_ATTN_PATH = "src/repro/core/attention.py"
_OPS_PATH = "src/repro/kernels/wave_attention/ops.py"
_DIST_PATH = "src/repro/core/distributed.py"


def _sds(tree):
    import jax
    return jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(np.shape(x), x.dtype), tree)


def _bf16_wave_setup():
    """A real (tiny) wave-index build whose payload fields are recast to
    bf16 — shapes come from ``prefill_build`` so the trace geometry always
    matches what the decode entry points expect."""
    import jax.numpy as jnp
    from repro.configs.base import RetroConfig
    from repro.core.wave_index import prefill_build, max_clusters
    from repro.core.zones import plan_zones

    retro = RetroConfig(avg_cluster=64, cluster_cap=256,
                        prefill_segment=1024, update_segment=256,
                        sink=16, local=256, retrieval_frac=0.1,
                        estimation_frac=0.3, kmeans_iters=1)
    B, Hkv, hd, n = 2, 2, 64, 2048
    rng = np.random.default_rng(0)
    k = jnp.asarray(rng.standard_normal((B, n, Hkv, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, n, Hkv, hd)), jnp.float32)
    M = max_clusters(n, retro)
    state = prefill_build(k, v, retro, M)
    bf16 = {"k_store", "v_store", "sink_k", "sink_v", "local_k", "local_v"}
    state = state._replace(**{
        f: getattr(state, f).astype(jnp.bfloat16) for f in bf16})
    plan = plan_zones(n, retro)
    q = jnp.zeros((B, 2 * Hkv, hd), jnp.bfloat16)
    return q, state, retro, plan


def _pallas_avals(double_buffer: bool):
    """ops.paged_wave_attention at bf16 stores, emulate=False — the trace
    contains the real ``pallas_call`` whose kernel body retronum inlines."""
    import functools
    import jax
    import jax.numpy as jnp
    from repro.kernels.wave_attention import ops

    B, H, G, hd, M, cap, r, E, Lb, S = 2, 2, 2, 64, 16, 128, 4, 128, 512, 16
    sd, f32, i32 = jnp.bfloat16, jnp.float32, jnp.int32
    a = jax.ShapeDtypeStruct
    avals = (a((B, H, G, hd), sd),                     # qg
             a((B, H, S, hd), sd), a((B, H, S, hd), sd),    # sink
             a((B, H, Lb, hd), sd), a((B, H, Lb, hd), sd),  # local
             a((B, H, Lb), i32),                            # local_pos
             a((B, H, M, cap, hd), sd), a((B, H, M, cap, hd), sd),
             a((B, H, M, cap), i32),                        # stores
             a((B, H, r), i32), a((B, H, r), i32),          # idx_r, live
             a((B, H, 2), i32),                             # rowb
             a((B, H, G, E), f32), a((B, H, G, E), f32),    # est_logit, cs
             a((B, H, E, hd), f32))                         # vs
    fn = functools.partial(ops.paged_wave_attention, softcap=None,
                           block_l=Lb, interpret=False, emulate=False,
                           double_buffer=double_buffer)
    return fn, avals


def run_numerics_checks(verbose=None) -> List[Finding]:
    """The full retronum repo gate: every curated decode entry point traced
    at bf16 payload dtypes and checked against the default f32 contract.
    Returns errors plus the RL406 cast-site inventory (advice)."""
    import functools
    import jax
    import jax.numpy as jnp
    from repro.core import attention as attn
    from repro.core.distributed import distributed_wave_attention

    log = verbose or (lambda *_: None)
    findings: List[Finding] = []
    inventory: List[Finding] = []

    # 1. dense-cache fallback decode + append, bf16 cache (the dense path
    # is full attention — a whole-cache upcast here is the RL402(b) catch)
    log("retronum: tracing dense-cache fallback (bf16 cache)")
    B, Hkv, S, hd = 2, 4, 8192, 128
    a = jax.ShapeDtypeStruct
    cache = attn.DenseCache(a((B, Hkv, S, hd), jnp.bfloat16),
                            a((B, Hkv, S, hd), jnp.bfloat16),
                            a((B,), jnp.int32))
    q = a((B, 2 * Hkv, hd), jnp.bfloat16)
    findings += numerics_findings(
        attn.full_attention_decode, (q, cache), "full_attention_decode",
        path=_ATTN_PATH)
    findings += numerics_findings(
        attn.dense_cache_append,
        (cache, a((B, Hkv, hd), jnp.float32), a((B, Hkv, hd), jnp.float32)),
        "dense_cache_append", path=_ATTN_PATH)

    # 2-4. the wave zone walk at bf16 stores: reference jnp path, the
    # fused path (resolves to the ref emulation on CPU — same zone walk the
    # serve hot path runs), and the return_parts LSE-merge boundary
    log("retronum: tracing wave decode (jnp + fused emulation, bf16 store)")
    qw, state, retro, plan = _bf16_wave_setup()
    st_avals = _sds(state)
    for impl in ("jnp", "fused"):
        fn = functools.partial(attn.wave_attention_decode, retro=retro,
                               plan=plan, impl=impl)
        findings += numerics_findings(
            fn, (_sds(qw), st_avals), f"wave_attention_decode[{impl}]",
            path=_ATTN_PATH)
    parts = functools.partial(
        attn.wave_attention_decode, retro=retro, plan=plan, impl="jnp",
        return_parts=True)
    findings += parts_findings(
        lambda q, s: parts(q, s)[:3], (_sds(qw), st_avals),
        "wave_attention_decode[parts]", path=_ATTN_PATH)

    # 5. the paged Pallas kernel, both cluster-walk flavors: in-kernel
    # precision rules + the RL406 VMEM cast-site inventory
    for db in (True, False):
        log(f"retronum: tracing paged kernel (double_buffer={db})")
        fn, avals = _pallas_avals(db)
        findings += numerics_findings(
            fn, avals, f"paged_wave_attention[db={int(db)}]",
            path=_OPS_PATH, inventory=inventory)

    # 6. the distributed LSE merge (shard_map body: psum/pmax collectives)
    log("retronum: tracing distributed LSE merge (1-device mesh)")
    try:
        mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("model",))
        fn = functools.partial(distributed_wave_attention, retro=retro,
                               plan=plan, mesh=mesh)
        findings += numerics_findings(
            fn, (_sds(qw.astype(jnp.float32)), st_avals),
            "distributed_wave_attention", path=_DIST_PATH)
    except Exception as e:
        findings.append(Finding(
            "RL405", _DIST_PATH, 0, "distributed_wave_attention",
            f"LSE-merge target could not be traced: {e!r}"))

    # de-duplicate inventory across the two kernel flavors (shared fold
    # helpers trace the same source site twice)
    seen, uniq = set(), []
    for f in inventory:
        key = (f.path, f.line, f.message)
        if key not in seen:
            seen.add(key)
            uniq.append(f)
    log(f"retronum: {len(uniq)} certified VMEM cast sites, "
        f"{len(findings)} findings")
    return findings + uniq


def kernel_cast_inventory() -> List[Finding]:
    """Just the RL406 advice inventory (used by the selftest)."""
    return [f for f in run_numerics_checks() if f.rule == "RL406"]
