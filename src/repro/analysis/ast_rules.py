"""Source-level lint rules (RL001-RL004) over the repro tree.

The pass is purely lexical — no imports are executed. Each rule documents
its (known, intentional) imprecision in ``findings.RULES``; the design goal
is zero false positives on the shipped tree with pragmas only at the
sanctioned sync sites, not completeness against adversarial code.
"""
from __future__ import annotations

import ast
import os
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

from repro.analysis.findings import Finding, Pragmas

# Functions on the decode hot path by qualname, per repo-relative path
# suffix. Functions tagged `# retrolint: hot` on their def line are hot
# everywhere, config-free (new code should prefer the tag).
HOT_PATHS: Dict[str, Tuple[str, ...]] = {
    "src/repro/serving/engine.py": (
        "ServeEngine.serve",
        "ServeEngine._sample",
        "_OffloadPlane.decode_step",
        "_OffloadPlane.flush",
        "_OffloadPlane.admit_slot",
        "_OffloadPlane._translate",
        "_OffloadPlane._drain_admissions",
    ),
}

# (module alias attr chain) call patterns that block on the device stream
_SYNC_FUNCS = {("np", "asarray"), ("np", "array"), ("numpy", "asarray"),
               ("numpy", "array"), ("jax", "device_get"),
               ("jax", "block_until_ready")}
_SYNC_METHODS = {"item", "block_until_ready"}

# attribute/metadata accesses that yield STATIC (untraced) values
_UNTAINT_ATTRS = {"shape", "ndim", "dtype", "size"}
_UNTAINT_CALLS = {"len", "range", "enumerate", "zip", "isinstance", "type",
                  "getattr", "hasattr"}


def _attr_chain(node: ast.AST) -> Tuple[str, ...]:
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
    return tuple(reversed(parts))


def _is_jax_jit(node: ast.AST) -> bool:
    """True for the expression ``jax.jit`` (or a bare ``jit`` import)."""
    chain = _attr_chain(node)
    return chain in (("jax", "jit"), ("jit",))


def _jit_call_info(call: ast.Call) -> Optional[dict]:
    """If ``call`` constructs a jit (``jax.jit(...)`` or
    ``[functools.]partial(jax.jit, ...)``), return its keyword info."""
    if isinstance(call.func, (ast.Attribute, ast.Name)) \
            and _is_jax_jit(call.func):
        return {"kw": {k.arg: k.value for k in call.keywords}}
    chain = _attr_chain(call.func)
    if chain and chain[-1] == "partial" and call.args \
            and _is_jax_jit(call.args[0]):
        return {"kw": {k.arg: k.value for k in call.keywords}}
    return None


def _literal_or_none(node: Optional[ast.AST]):
    try:
        return ast.literal_eval(node) if node is not None else None
    except (ValueError, TypeError):
        return None


def _jitted_decorator(fn: ast.FunctionDef) -> Optional[dict]:
    """jit info if the def is decorated @jax.jit / @partial(jax.jit, ...)."""
    for dec in fn.decorator_list:
        if isinstance(dec, (ast.Attribute, ast.Name)) and _is_jax_jit(dec):
            return {"kw": {}}
        if isinstance(dec, ast.Call):
            info = _jit_call_info(dec)
            if info is not None:
                return info
    return None


class _QualnameVisitor(ast.NodeVisitor):
    """Base visitor tracking the enclosing def/class qualname."""

    def __init__(self) -> None:
        self.stack: List[str] = []

    @property
    def qualname(self) -> str:
        return ".".join(self.stack) or "<module>"

    def visit_ClassDef(self, node: ast.ClassDef) -> None:
        self.stack.append(node.name)
        self.generic_visit(node)
        self.stack.pop()

    def _visit_fn(self, node):
        self.stack.append(node.name)
        self.generic_visit(node)
        self.stack.pop()

    visit_FunctionDef = _visit_fn
    visit_AsyncFunctionDef = _visit_fn


# ------------------------------------------------------------------- RL001
def _check_hot_syncs(tree: ast.Module, path: str, pragmas: Pragmas,
                     hot_qualnames: Sequence[str]) -> List[Finding]:
    findings: List[Finding] = []

    class V(_QualnameVisitor):
        def __init__(self) -> None:
            super().__init__()
            self.hot_depth = 0

        def _visit_fn(self, node):
            is_hot = False
            self.stack.append(node.name)
            if self.qualname in hot_qualnames \
                    or pragmas.marks_hot(node.lineno):
                is_hot = True
            self.hot_depth += is_hot
            self.generic_visit(node)
            self.hot_depth -= is_hot
            self.stack.pop()

        visit_FunctionDef = _visit_fn
        visit_AsyncFunctionDef = _visit_fn

        def visit_Call(self, node: ast.Call) -> None:
            if self.hot_depth:
                chain = _attr_chain(node.func)
                hit = None
                if chain[-2:] in _SYNC_FUNCS or chain in _SYNC_FUNCS:
                    hit = ".".join(chain)
                elif isinstance(node.func, ast.Attribute) \
                        and node.func.attr in _SYNC_METHODS \
                        and len(chain) != 2:
                    # x.item() / x.block_until_ready(); the len-2 module
                    # forms (jax.block_until_ready) are handled above
                    hit = f".{node.func.attr}()"
                if hit and not (pragmas.sanctions_sync(node.lineno)
                                or pragmas.ignores(node.lineno, "RL001")):
                    findings.append(Finding(
                        "RL001", path, node.lineno, self.qualname,
                        f"host sync `{hit}` on the decode hot path without "
                        f"a `# retrolint: sync(<reason>)` pragma"))
            self.generic_visit(node)

    V().visit(tree)
    return findings


# ------------------------------------------------------------------- RL002
class _TaintChecker:
    """Per-function taint walk: parameters of a jitted function (minus
    static_argnames) are traced; flag Python control flow on traced values."""

    def __init__(self, fn: ast.FunctionDef, static_names: Set[str]) -> None:
        self.fn = fn
        args = fn.args
        names = [a.arg for a in
                 args.posonlyargs + args.args + args.kwonlyargs]
        if args.vararg:
            names.append(args.vararg.arg)
        if args.kwarg:
            names.append(args.kwarg.arg)
        self.tainted: Set[str] = {n for n in names if n not in static_names}

    def expr_tainted(self, node: ast.AST) -> bool:
        if isinstance(node, ast.Name):
            return node.id in self.tainted
        if isinstance(node, ast.Attribute):
            if node.attr in _UNTAINT_ATTRS:
                return False
            return self.expr_tainted(node.value)
        if isinstance(node, ast.Subscript):
            return self.expr_tainted(node.value)
        if isinstance(node, (ast.BinOp,)):
            return self.expr_tainted(node.left) or \
                self.expr_tainted(node.right)
        if isinstance(node, ast.UnaryOp):
            return self.expr_tainted(node.operand)
        if isinstance(node, ast.BoolOp):
            return any(self.expr_tainted(v) for v in node.values)
        if isinstance(node, ast.Compare):
            # `is (not) None` and friends are static identity checks
            if all(isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops):
                return False
            return self.expr_tainted(node.left) or \
                any(self.expr_tainted(c) for c in node.comparators)
        if isinstance(node, ast.Call):
            chain = _attr_chain(node.func)
            if chain and chain[0] in _UNTAINT_CALLS and len(chain) == 1:
                return False
            return any(self.expr_tainted(a) for a in node.args) or \
                any(self.expr_tainted(k.value) for k in node.keywords)
        if isinstance(node, (ast.Tuple, ast.List)):
            return any(self.expr_tainted(e) for e in node.elts)
        if isinstance(node, ast.IfExp):
            return any(self.expr_tainted(e)
                       for e in (node.test, node.body, node.orelse))
        return False

    def run(self, path: str, qualname: str,
            pragmas: Pragmas) -> List[Finding]:
        findings: List[Finding] = []

        def flag(node, what, expr):
            if not pragmas.ignores(node.lineno, "RL002"):
                findings.append(Finding(
                    "RL002", path, node.lineno, qualname,
                    f"Python `{what}` on a traced value inside a jitted "
                    f"function (use lax.cond/select/scan)"))

        for node in ast.walk(self.fn):
            if isinstance(node, ast.Assign):
                if self.expr_tainted(node.value):
                    for t in node.targets:
                        for n in ast.walk(t):
                            if isinstance(n, ast.Name):
                                self.tainted.add(n.id)
        for node in ast.walk(self.fn):
            if isinstance(node, ast.If) and self.expr_tainted(node.test):
                flag(node, "if", node.test)
            elif isinstance(node, ast.While) \
                    and self.expr_tainted(node.test):
                flag(node, "while", node.test)
            elif isinstance(node, ast.For) and self.expr_tainted(node.iter):
                flag(node, "for", node.iter)
        return findings


def _check_traced_branches(tree: ast.Module, path: str,
                           pragmas: Pragmas) -> List[Finding]:
    findings: List[Finding] = []

    class V(_QualnameVisitor):
        def _visit_fn(self, node):
            self.stack.append(node.name)
            info = _jitted_decorator(node)
            if info is not None:
                statics = _literal_or_none(
                    info["kw"].get("static_argnames")) or ()
                if isinstance(statics, str):
                    statics = (statics,)
                findings.extend(
                    _TaintChecker(node, set(statics)).run(
                        path, self.qualname, pragmas))
            self.generic_visit(node)
            self.stack.pop()

        visit_FunctionDef = _visit_fn
        visit_AsyncFunctionDef = _visit_fn

    V().visit(tree)
    return findings


# ------------------------------------------------------------------- RL003
def _check_jit_in_loop(tree: ast.Module, path: str,
                       pragmas: Pragmas) -> List[Finding]:
    findings: List[Finding] = []

    class V(_QualnameVisitor):
        def __init__(self) -> None:
            super().__init__()
            self.loop_depth = 0

        def _visit_loop(self, node):
            self.loop_depth += 1
            self.generic_visit(node)
            self.loop_depth -= 1

        visit_For = _visit_loop
        visit_While = _visit_loop
        visit_AsyncFor = _visit_loop

        def _visit_fn(self, node):
            # a def inside a loop resets the loop context: building a jit
            # inside a (cached) builder that happens to sit in a loop is
            # the builder's problem, not this call site's
            saved, self.loop_depth = self.loop_depth, 0
            self.stack.append(node.name)
            self.generic_visit(node)
            self.stack.pop()
            self.loop_depth = saved

        visit_FunctionDef = _visit_fn
        visit_AsyncFunctionDef = _visit_fn

        def visit_Call(self, node: ast.Call) -> None:
            if self.loop_depth and _jit_call_info(node) is not None \
                    and not pragmas.ignores(node.lineno, "RL003"):
                findings.append(Finding(
                    "RL003", path, node.lineno, self.qualname,
                    "jax.jit constructed inside a loop body (fresh "
                    "compilation cache every iteration) — hoist it out"))
            self.generic_visit(node)

    V().visit(tree)
    return findings


# ------------------------------------------------------------------- RL004
def _donated_bindings(tree: ast.Module) -> Dict[str, Tuple[int, ...]]:
    """Names (or attribute names: ``self._graft`` -> ``_graft``) bound to a
    jit with literal donate_argnums, module-wide. Also covers decorated
    defs (the def's own name is the binding)."""
    out: Dict[str, Tuple[int, ...]] = {}

    def record(target: ast.AST, don) -> None:
        if don is None:
            return
        don = (don,) if isinstance(don, int) else tuple(don)
        if isinstance(target, ast.Name):
            out[target.id] = don
        elif isinstance(target, ast.Attribute):
            out[target.attr] = don

    for node in ast.walk(tree):
        if isinstance(node, ast.Assign):
            call = node.value
            # f = jax.jit(g, donate_argnums=...) and
            # f = partial(jax.jit, donate_argnums=...)(g)
            for c in ast.walk(call):
                if isinstance(c, ast.Call):
                    info = _jit_call_info(c)
                    if info is not None:
                        don = _literal_or_none(
                            info["kw"].get("donate_argnums"))
                        for t in node.targets:
                            record(t, don)
        elif isinstance(node, ast.FunctionDef):
            info = _jitted_decorator(node)
            if info is not None:
                don = _literal_or_none(info["kw"].get("donate_argnums"))
                record(ast.Name(id=node.name), don)
    return out


def _name_of(node: ast.AST) -> Optional[str]:
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):        # track `adm.cstate` textually
        chain = _attr_chain(node)
        return ".".join(chain) if chain else None
    return None


def _check_donated_reuse(tree: ast.Module, path: str,
                         pragmas: Pragmas) -> List[Finding]:
    donors = _donated_bindings(tree)
    if not donors:
        return []
    findings: List[Finding] = []

    class V(_QualnameVisitor):
        def _visit_fn(self, fn):
            self.stack.append(fn.name)
            self._scan_fn(fn, self.qualname)
            self.generic_visit(fn)
            self.stack.pop()

        visit_FunctionDef = _visit_fn
        visit_AsyncFunctionDef = _visit_fn

        def _scan_fn(self, fn, qualname: str) -> None:
            loads: Dict[str, List[int]] = {}
            stores: Dict[str, List[int]] = {}
            loops: List[Tuple[int, int]] = []
            for node in ast.walk(fn):
                if isinstance(node, (ast.For, ast.While)):
                    loops.append((node.lineno, node.end_lineno or node.lineno))
                nm = _name_of(node)
                if nm is None:
                    continue
                ctx = getattr(node, "ctx", None)
                if isinstance(ctx, ast.Load):
                    loads.setdefault(nm, []).append(node.lineno)
                elif isinstance(ctx, (ast.Store, ast.Del)):
                    stores.setdefault(nm, []).append(node.lineno)

            for node in ast.walk(fn):
                if not isinstance(node, ast.Call):
                    continue
                callee = _name_of(node.func)
                short = callee.rsplit(".", 1)[-1] if callee else None
                if short not in donors:
                    continue
                for pos in donors[short]:
                    if pos >= len(node.args):
                        continue
                    arg = _name_of(node.args[pos])
                    if arg is None:
                        continue
                    line = node.lineno
                    if pragmas.ignores(line, "RL004"):
                        continue
                    later = [ln for ln in loads.get(arg, []) if ln > line]
                    rebinds = stores.get(arg, [])
                    bad = next(
                        (ln for ln in later
                         if not any(line <= s <= ln for s in rebinds)),
                        None)
                    if bad is not None:
                        findings.append(Finding(
                            "RL004", path, bad, qualname,
                            f"`{arg}` was donated to `{short}` (arg {pos}) "
                            f"and is read again after the call — rebind it "
                            f"from the result"))
                        continue
                    # call sits in a loop and the donated name is never
                    # rebound inside it: iteration 2 re-donates a dead buffer
                    for lo, hi in loops:
                        if lo <= line <= hi and not any(
                                lo <= s <= hi for s in rebinds):
                            findings.append(Finding(
                                "RL004", path, line, qualname,
                                f"`{arg}` is donated to `{short}` inside a "
                                f"loop but never rebound in the loop body — "
                                f"the next iteration reuses a donated "
                                f"buffer"))
                            break

    V().visit(tree)
    return findings


# ------------------------------------------------------------------ driver
def lint_source(source: str, path: str,
                hot_qualnames: Sequence[str] = ()) -> List[Finding]:
    """All AST rules over one file's source. ``path`` is repo-relative."""
    tree = ast.parse(source, filename=path)
    pragmas = Pragmas.scan(source)
    hot = tuple(hot_qualnames)
    for suffix, quals in HOT_PATHS.items():
        if path.endswith(suffix) or suffix.endswith(path):
            hot = hot + quals
    findings = []
    findings += _check_hot_syncs(tree, path, pragmas, hot)
    findings += _check_traced_branches(tree, path, pragmas)
    findings += _check_jit_in_loop(tree, path, pragmas)
    findings += _check_donated_reuse(tree, path, pragmas)
    return findings


def lint_tree(root: str, subdirs: Iterable[str] = ("src",)) -> List[Finding]:
    findings: List[Finding] = []
    for sub in subdirs:
        base = os.path.join(root, sub)
        for dirpath, _dirs, files in os.walk(base):
            for name in sorted(files):
                if not name.endswith(".py"):
                    continue
                full = os.path.join(dirpath, name)
                rel = os.path.relpath(full, root).replace(os.sep, "/")
                with open(full) as f:
                    findings += lint_source(f.read(), rel)
    return findings
