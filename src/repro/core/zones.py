"""Tripartite zone planning (paper Sec. 4.2).

Given a context length and the RetroConfig budgets, compute the static sizes of
the retrieval zone (r clusters, fetched + exact attention) and estimation zone
(e clusters, centroid-estimated). The steady zone is fixed (sink + local).
"""
from __future__ import annotations

from typing import NamedTuple

from repro.configs.base import RetroConfig
from repro.core.wave_index import max_clusters, prefill_layout


class ZonePlan(NamedTuple):
    m_max: int          # static cluster-store size
    r: int              # retrieval-zone clusters
    e: int              # estimation-zone clusters
    sink: int
    local_buf: int      # staging buffer (local window + update segment)

    @property
    def exec_tokens(self) -> int:
        """Execution-buffer token slots (steady + retrieved)."""
        return self.sink + self.local_buf


def plan_zones(seq_len: int, retro: RetroConfig, gen_headroom: int = 4096) -> ZonePlan:
    """Prompts shorter than sink + local degrade to a steady-zone-only plan:
    prefill_layout clamps the clustered region to zero, so r = e = 0 and the
    cluster store keeps only decode-flush headroom."""
    _, _, m_prefill = prefill_layout(seq_len, retro)
    m_max = max_clusters(seq_len, retro, gen_headroom)
    r = min(retro.r_clusters(seq_len), m_prefill)
    e = min(retro.e_clusters(seq_len), max(0, m_prefill - r))
    return ZonePlan(m_max=m_max, r=r, e=e, sink=retro.sink,
                    local_buf=retro.local + retro.update_segment)
