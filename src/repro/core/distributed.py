"""Distributed wave attention — beyond-paper sharded retrieval (DESIGN §6).

Baseline (paper-faithful under pjit): the cluster stores are sharded over the
'model' axis, the global top-r gather crosses shards, and XLA materializes the
retrieved KV blocks with all-gather/all-reduce collectives whose payload is
O(r · cap · hd) *KV bytes* per head per step.

This module replaces that with LOCAL retrieval: every shard ranks only its
local clusters, retrieves its local top-⌈r/n⌉ (+ local estimation zone), and
computes a partial flash merge (num, den, m). Shards then combine with one
pmax + psum whose payload is O(B · H · G · (hd + 2)) floats — independent of
r and cap. The steady zone is contributed by shard 0 only.

Quality note: the union of per-shard top-⌈r/n⌉ is not exactly the global
top-r; segmented clustering spreads hot clusters across shards (cluster ids
are segment-major), and the estimation zone covers stragglers — measured in
tests/test_distributed.py.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import RetroConfig
from repro.core.attention import wave_attention_decode
from repro.core.wave_index import WaveState
from repro.core.zones import ZonePlan


def _shard_map(body, mesh, in_specs, out_specs, axis_names):
    """Version shim: jax >= 0.6 exposes jax.shard_map (axis_names/check_vma);
    earlier releases ship jax.experimental.shard_map (check_rep)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(body, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, axis_names=axis_names,
                             check_vma=False)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)


def local_plan(plan: ZonePlan, n_shards: int) -> ZonePlan:
    return plan._replace(r=max(1, math.ceil(plan.r / n_shards)),
                         e=max(1, math.ceil(plan.e / n_shards)))


def shard_wave_attention(q, state: WaveState, retro: RetroConfig,
                         plan: ZonePlan, *, axis: str = "model",
                         window=None, softcap=None, shard_id=None):
    """Body function — must run under shard_map with the cluster axis of
    ``state`` sharded over ``axis``. q: (B, Hq, hd) replicated over ``axis``.
    Returns (B, Hq, hd) replicated over ``axis``.

    ``shard_id``: (1,) int32 operand sharded over ``axis`` (an arange split
    across shards). Used instead of lax.axis_index, which lowers to a
    PartitionId op that SPMD can't partition when other mesh axes stay auto.
    """
    B, Hq, hd = q.shape
    # jax >= 0.6 has lax.axis_size; older releases statically fold psum(1, ax)
    n_sh = jax.lax.axis_size(axis) if hasattr(jax.lax, "axis_size") \
        else jax.lax.psum(1, axis)
    ax = shard_id[0] if shard_id is not None else jax.lax.axis_index(axis)
    m_loc = state.centroid.shape[2]
    lp = local_plan(plan, n_sh)
    # clamp to the local shard's cluster count (full-coverage case)
    r_loc = min(lp.r, m_loc)
    e_loc = min(lp.e, m_loc - r_loc)
    lp = lp._replace(r=r_loc, e=e_loc)
    num, den, m, _ = wave_attention_decode(
        q, state, retro, lp, window=window, softcap=softcap,
        cluster_offset=ax * m_loc, include_steady=(ax == 0),
        return_parts=True)
    m_glob = jax.lax.pmax(m, axis)
    scale = jnp.exp(m - m_glob)
    num = jax.lax.psum(num * scale[..., None], axis)
    den = jax.lax.psum(den * scale, axis)
    out = num / jnp.maximum(den, 1e-30)[..., None]
    return out.reshape(B, Hq, hd).astype(q.dtype)


def state_specs_cluster_sharded(state: WaveState, axis: str = "model"):
    """PartitionSpecs for a per-layer WaveState with the cluster axis sharded
    (per-layer leaves: (B, H, M, ...))."""
    def spec(name, leaf):
        nd = leaf.ndim
        if name in ("k_store", "v_store", "pos_store", "centroid", "vsum",
                    "size", "stored", "max_pos"):
            s = [None] * nd
            s[2] = axis
            return P(*s)
        return P(*([None] * nd))

    return WaveState(*[spec(f, getattr(state, f))
                       for f in WaveState._fields])


def distributed_wave_attention(q, state: WaveState, retro: RetroConfig,
                               plan: ZonePlan, mesh, *, axis: str = "model",
                               window=None, softcap=None):
    """shard_map wrapper: q replicated on ``axis``, state cluster-sharded.

    ``window`` may be a traced scalar — passed as an explicit (replicated)
    shard_map operand rather than captured in the closure."""
    manual = frozenset({axis})
    state_specs = state_specs_cluster_sharded(state, axis)
    n_sh = mesh.shape[axis]
    shard_ids = jnp.arange(n_sh, dtype=jnp.int32)

    if window is not None:
        def body(q, s, sid, w):
            return shard_wave_attention(q, s, retro, plan, axis=axis,
                                        window=w, softcap=softcap,
                                        shard_id=sid)
        fn = _shard_map(body, mesh, (P(), state_specs, P(axis), P()),
                        P(), manual)
        return fn(q, state, shard_ids, jnp.asarray(window, jnp.float32))

    def body(q, s, sid):
        return shard_wave_attention(q, s, retro, plan, axis=axis,
                                    window=None, softcap=softcap,
                                    shard_id=sid)
    fn = _shard_map(body, mesh, (P(), state_specs, P(axis)), P(), manual)
    return fn(q, state, shard_ids)
