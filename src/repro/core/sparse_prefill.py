"""Block-sparse prefill attention (paper Sec. 5.2 "Compatibility with Sparse
Prefilling", Fig. 12 — XAttention / MInference flavored).

RetroInfer optimizes decoding; prefill remains quadratic. The paper shows it
composes with sparse-prefill methods at ~1.5% accuracy cost. This module
implements a block top-k sparse prefill: keys are summarized per block (mean
key), each query block selects its top-k key blocks by summary score (sinks +
the local diagonal band are always kept), and exact attention runs only over
the selected blocks. The wave-index build is unaffected — it consumes the
same K/V the sparse pass produces.

Pure jnp with static shapes: (T/bs query blocks) x (sel selected key blocks).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.layers import _repeat_kv, soft_cap

NEG = -1e30


def block_sparse_attention(q, k, v, *, block: int = 128,
                           topk_blocks: int = 16, sink_blocks: int = 1,
                           local_blocks: int = 2,
                           window: Optional[float] = None,
                           softcap: Optional[float] = None):
    """Causal block-sparse attention.

    q: (B, T, Hq, hd); k, v: (B, T, Hkv, hd); T % block == 0.
    Selection is per (kv-head, query-block): top ``topk_blocks`` key blocks by
    q-block-mean x k-block-mean score, plus forced sink/local blocks.
    Returns (B, T, Hq, hd).
    """
    B, T, Hq, hd = q.shape
    Hkv = k.shape[2]
    n_rep = Hq // Hkv
    assert T % block == 0, (T, block)
    nb = T // block
    sel = min(nb, topk_blocks + sink_blocks + local_blocks)
    scale = 1.0 / math.sqrt(hd)

    kr = _repeat_kv(k, n_rep)
    vr = _repeat_kv(v, n_rep)

    # block summaries (f32): mean query / mean key per block
    qb = q.reshape(B, nb, block, Hq, hd).mean(axis=2).astype(jnp.float32)
    kb = k.reshape(B, nb, block, Hkv, hd).mean(axis=2).astype(jnp.float32)
    s_blk = jnp.einsum("bqhd,bkgd->bhqk",
                       qb.reshape(B, nb, Hkv, n_rep, hd).mean(axis=3),
                       kb) * scale                        # (B, Hkv, nb, nb)
    causal = jnp.tril(jnp.ones((nb, nb), bool))
    s_blk = jnp.where(causal[None, None], s_blk, NEG)
    # force sinks + local diagonal band
    qi = jnp.arange(nb)[:, None]
    ki = jnp.arange(nb)[None, :]
    forced = (ki < sink_blocks) | ((ki <= qi) & (ki > qi - local_blocks))
    s_blk = jnp.where(forced[None, None], jnp.inf, s_blk)
    _, blk_idx = jax.lax.top_k(s_blk, sel)                # (B, Hkv, nb, sel)

    # gather selected key/value blocks per (B, Hkv-group, q-block)
    k4 = kr.reshape(B, nb, block, Hq, hd)
    v4 = vr.reshape(B, nb, block, Hq, hd)
    blk_idx_h = jnp.repeat(blk_idx, n_rep, axis=1)        # (B, Hq, nb, sel)

    def gather_blocks(x4, idx):
        # x4: (B, nb, block, Hq, hd); idx: (B, Hq, nb, sel)
        xh = jnp.moveaxis(x4, 3, 1)                       # (B, Hq, nb, blk, hd)
        out = jnp.take_along_axis(
            xh[:, :, None], idx[..., None, None], axis=3) # (B,Hq,nb,sel,blk,hd)
        return out

    ks = gather_blocks(k4, blk_idx_h)
    vs = gather_blocks(v4, blk_idx_h)

    qf = q.reshape(B, nb, block, Hq, hd)
    qf = jnp.moveaxis(qf, 3, 1).astype(jnp.float32)       # (B,Hq,nb,blk,hd)
    s = jnp.einsum("bhnqd,bhnskd->bhnqsk", qf,
                   ks.astype(jnp.float32)) * scale        # (...,q,sel,blk)
    s = soft_cap(s, softcap)

    # causal + window masking at token granularity
    q_pos = (jnp.arange(nb)[:, None] * block
             + jnp.arange(block)[None, :])                # (nb, blk)
    k_pos = (blk_idx_h[..., None] * block
             + jnp.arange(block))                         # (B,Hq,nb,sel,blk)
    ok = k_pos[:, :, :, None] <= q_pos[None, None, :, :, None, None]
    if window is not None:
        ok = ok & (k_pos[:, :, :, None]
                   > q_pos[None, None, :, :, None, None] - window)
    s = jnp.where(ok, s, NEG)

    m = jnp.max(s, axis=(-2, -1), keepdims=True)
    m = jnp.maximum(m, -1e20)
    p = jnp.exp(s - m)
    p = jnp.where(ok, p, 0.0)
    den = jnp.sum(p, axis=(-2, -1))
    num = jnp.einsum("bhnqsk,bhnskd->bhnqd", p, vs.astype(jnp.float32))
    out = num / jnp.maximum(den, 1e-30)[..., None]
    out = jnp.moveaxis(out, 1, 3).reshape(B, T, Hq, hd)   # (B,nb,blk,Hq,hd)->
    return out.astype(q.dtype)
