"""Wave buffer — the accuracy-agnostic buffer manager (paper Sec. 4.3).

On a real TPU deployment the KV store lives in sharded HBM and the "cache" is
HBM itself (DESIGN §2). This module implements the paper's *host-offload*
configuration — KV blocks in host memory, a fixed-size device block cache,
an execution buffer assembled from {steady zone, cache hits, misses} — used by
the single-host serving driver and the cache benchmarks. Mirroring the paper:

* cluster -> block indirection via a mapping table (logical clusters may span
  multiple fixed-size physical blocks),
* synchronous cache *access* on the critical path, asynchronous (deferred,
  vectorized) cache *update* — LRU metadata is maintained off the hot path,
* hit/miss/transfer accounting to reproduce Fig. 16-style analyses.

The control plane is NumPy (the paper runs it on CPU threads); the data plane
arrays live wherever the caller puts them (device or host).

Fault model (retrofault)
------------------------
The miss-fetch path goes through a pluggable :class:`LinkTransport`. The
production transport is an infallible zero-copy read of the host store; the
seed-deterministic :class:`FaultyTransport` injects scheduled transient fetch
failures, latency spikes, and payload corruption for chaos testing. Integrity
and liveness are layered on top of the transport, not inside it:

* **Checksums** — one ``zlib.crc32`` per packed ``[K | V | pos]`` payload row,
  computed when the row is stored (buffer construction and
  :meth:`store_rows`, which the serve engine's segment flush uses) and
  verified on every transport fetch. A mismatch counts as
  ``corrupt_fetches`` and is treated like a transient fault (retried).
* **Bounded retry + exponential backoff** — a failed attempt costs
  ``backoff_s * 2**attempt`` on a *virtual* clock (no real sleeps, so fault
  schedules are deterministic and tests are fast); at most ``max_retries``
  retries per miss.
* **Deadline** — ``translate`` takes an optional virtual time budget shared
  by all misses of the call (the engine's per-step fetch deadline). A miss
  whose retries exhaust or whose budget runs out FAILS for this step: it is
  reported via the ``ok`` mask, stays out of the pending set, and is
  naturally refetched in a later update window (reconciliation). The caller
  masks the cluster out of the retrieval zone and covers its attention mass
  with the estimation zone.
* **Unrecoverable faults** — :class:`FatalTransportError` propagates to the
  caller (the serve engine finishes the affected request with
  ``status="error"``; other slots keep serving).
"""
from __future__ import annotations

import zlib
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np


class TransientFault(RuntimeError):
    """A fetch attempt failed recoverably (retry may succeed)."""


class FatalTransportError(RuntimeError):
    """The link is unrecoverably broken for this fetch (no retry)."""


@dataclass
class FaultProfile:
    """Seed-deterministic fault schedule for :class:`FaultyTransport`.

    Rates are per-attempt probabilities; ``seed`` fixes the schedule. The
    virtual latencies (``latency_s``, ``spike_s``) are charged against the
    translate call's deadline budget — never slept.
    """
    transient: float = 0.0      # P(attempt raises TransientFault)
    corrupt: float = 0.0        # P(payload corrupted in flight — crc catches)
    spike: float = 0.0          # P(latency spike on a successful attempt)
    fatal: float = 0.0          # P(attempt raises FatalTransportError)
    latency_s: float = 0.0      # base virtual latency per successful fetch
    spike_s: float = 0.05       # extra virtual latency of a spike
    seed: int = 0

    @classmethod
    def parse(cls, spec: str) -> "FaultProfile":
        """Parse ``"transient=0.2,corrupt=0.01,seed=3"``-style CLI specs."""
        kw: Dict[str, float] = {}
        for part in spec.split(","):
            part = part.strip()
            if not part:
                continue
            key, _, val = part.partition("=")
            if key not in cls.__dataclass_fields__:
                raise ValueError(
                    f"unknown fault-profile field {key!r} (known: "
                    f"{', '.join(cls.__dataclass_fields__)})")
            kw[key] = int(val) if key == "seed" else float(val)
        return cls(**kw)


class LinkTransport:
    """Pluggable host->device link for the miss-fetch path.

    ``fetch(store, cid)`` returns ``(payload_row, virtual_latency_s)``. The
    production transport is an infallible zero-copy view of the host store
    with zero virtual latency — byte-identical to the pre-transport code.
    """

    def fetch(self, store: np.ndarray, cid: int
              ) -> Tuple[np.ndarray, float]:
        return store[cid], 0.0


class FaultyTransport(LinkTransport):
    """Seed-deterministic fault injection over the link.

    Corruption happens on a COPY of the payload row (the host store is never
    damaged — this models a bit flip in flight, which the per-row crc32
    catches on arrival).
    """

    def __init__(self, profile: FaultProfile):
        self.profile = profile
        self.rng = np.random.default_rng(profile.seed)

    def fetch(self, store: np.ndarray, cid: int
              ) -> Tuple[np.ndarray, float]:
        p = self.profile
        if p.fatal and self.rng.random() < p.fatal:
            raise FatalTransportError(
                f"unrecoverable link failure fetching cluster {cid}")
        if p.transient and self.rng.random() < p.transient:
            raise TransientFault(f"transient fetch failure, cluster {cid}")
        lat = p.latency_s
        if p.spike and self.rng.random() < p.spike:
            lat += p.spike_s
        payload = store[cid]
        if p.corrupt and self.rng.random() < p.corrupt:
            payload = payload.copy()
            flat = payload.reshape(-1)
            flat[int(self.rng.integers(flat.size))] += 1.0
        return payload, lat


@dataclass
class BufferStats:
    lookups: int = 0
    hits: int = 0
    misses: int = 0
    bytes_from_cache: int = 0
    bytes_over_link: int = 0        # host->device traffic (the "PCIe" analogue)
    bytes_from_pending: int = 0     # repeat-miss bytes served from the pending set
    bytes_steady: int = 0
    updates_deferred: int = 0
    pending_hits: int = 0           # repeat misses served from the pending set
    faults: int = 0                 # transient fetch failures observed
    retries: int = 0                # retry attempts issued (with backoff)
    corrupt_fetches: int = 0        # crc32 mismatches caught on fetch
    failed_fetches: int = 0         # misses abandoned (retries/deadline out)

    @property
    def hit_ratio(self) -> float:
        return self.hits / max(1, self.lookups)

    @property
    def effective_hit_ratio(self) -> float:
        """Fig. 16-style effective hit rate: a pending hit never crosses the
        link again, so for traffic purposes it IS a hit — counting it as a
        plain miss (as ``hit_ratio`` alone would) understates the cache under
        repeat misses within one update window."""
        return (self.hits + self.pending_hits) / max(1, self.lookups)

    def merge(self, other: "BufferStats") -> None:
        """Accumulate another buffer's counters (engine-level aggregation)."""
        for f in ("lookups", "hits", "misses", "bytes_from_cache",
                  "bytes_over_link", "bytes_from_pending", "bytes_steady",
                  "updates_deferred", "pending_hits", "faults", "retries",
                  "corrupt_fetches", "failed_fetches"):
            setattr(self, f, getattr(self, f) + getattr(other, f))


class ClusterMappingTable:
    """Logical cluster -> physical block address translation (paper Fig. 9).

    Each cluster occupies ``blocks_per_cluster`` consecutive physical blocks in
    host memory; the table tracks, per cluster, the device-cache slot (or -1).
    Implemented as flat int arrays for O(1) vectorized lookup.
    """

    def __init__(self, n_clusters: int, blocks_per_cluster: int):
        self.blocks_per_cluster = blocks_per_cluster
        self.host_block = np.arange(n_clusters, dtype=np.int64) * blocks_per_cluster
        self.cache_slot = np.full(n_clusters, -1, dtype=np.int64)

    def lookup(self, cluster_ids: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """-> (cache_slot per cluster (-1 = miss), host_block per cluster)."""
        return self.cache_slot[cluster_ids], self.host_block[cluster_ids]


class WaveBuffer:
    """Device block cache + execution-buffer assembly with deferred LRU.

    ``kv_host``: (n_clusters, bytes_per_cluster) conceptual host store — here
    an ndarray of cluster payloads (keys+values flattened). The device cache
    holds ``cache_clusters`` payload rows.
    """

    def __init__(self, kv_host: np.ndarray, cache_clusters: int,
                 blocks_per_cluster: int = 1, policy: str = "lru",
                 transport: Optional[LinkTransport] = None,
                 max_retries: int = 2, backoff_s: float = 1e-3):
        assert policy in ("lru", "fifo", "clock")
        if cache_clusters < 0:
            raise ValueError(f"cache_clusters must be >= 0, got {cache_clusters}")
        # cache_clusters == 0 (tiny int(frac * n) configs round to zero) is an
        # explicit PASS-THROUGH: every lookup is a miss served over the link
        # (with pending-set dedup within an update window) and nothing is ever
        # admitted — not an accident of the _admit early-return path.
        self.passthrough = cache_clusters == 0
        self.kv_host = kv_host
        n = kv_host.shape[0]
        self.table = ClusterMappingTable(n, blocks_per_cluster)
        self.cache = np.zeros((cache_clusters,) + kv_host.shape[1:],
                              dtype=kv_host.dtype)
        self.cache_owner = np.full(cache_clusters, -1, dtype=np.int64)
        self.policy = policy
        self.clock_hand = 0
        self.ref_bit = np.zeros(cache_clusters, dtype=bool)
        self.stamp = np.zeros(cache_clusters, dtype=np.int64)   # LRU timestamps
        self.tick = 0
        self.stats = BufferStats()
        self._pending: List[Tuple[np.ndarray, np.ndarray]] = []
        self._pending_map: Dict[int, np.ndarray] = {}   # id -> fetched payload
        self.bytes_per_cluster = int(kv_host[0].nbytes) if n else 0
        self.transport = transport if transport is not None else LinkTransport()
        self.max_retries = max_retries
        self.backoff_s = backoff_s
        self.checksums = np.array(
            [zlib.crc32(kv_host[i].tobytes()) for i in range(n)],
            dtype=np.uint64)

    # ------------------------------------------------------------------- store
    def store_rows(self, start: int, rows: np.ndarray) -> None:
        """Write packed payload rows ``[start, start+len)`` into the host
        store and refresh their checksums (the serve engine's segment flush
        MUST come through here — a raw ``kv_host[...] = ...`` slice write
        would leave stale crcs and every later fetch of those clusters would
        count as corrupt)."""
        self.kv_host[start:start + len(rows)] = rows
        for i in range(start, start + len(rows)):
            self.checksums[i] = zlib.crc32(self.kv_host[i].tobytes())

    # ------------------------------------------------------------------- fetch
    def _fetch(self, cid: int, budget: Optional[float]
               ) -> Tuple[Optional[np.ndarray], float]:
        """One miss fetch through the transport, with crc verification,
        bounded retry + exponential virtual backoff, and a virtual deadline
        budget. Returns ``(payload_or_None, virtual_seconds_spent)``.
        ``FatalTransportError`` propagates (the caller fails the request)."""
        spent = 0.0
        for attempt in range(self.max_retries + 1):
            if attempt:
                self.stats.retries += 1
                spent += self.backoff_s * (2 ** (attempt - 1))
            if budget is not None and spent > budget:
                return None, spent              # overdue before issuing
            try:
                payload, lat = self.transport.fetch(self.kv_host, cid)
            except TransientFault:
                self.stats.faults += 1
                continue
            spent += lat
            if budget is not None and spent > budget:
                return None, spent              # arrived past the deadline
            if zlib.crc32(payload.tobytes()) != int(self.checksums[cid]):
                self.stats.corrupt_fetches += 1
                continue
            return payload, spent
        return None, spent

    # ------------------------------------------------------------------ access
    def translate(self, cluster_ids: np.ndarray, deadline_s: Optional[float] = None
                  ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
        """Control-plane access for one decode step (synchronous).

        Returns ``(slot, hit, miss_payload, ok)``: per-id device-cache slot
        (>= 0 for hits, -1 for misses), the hit mask, the host payload of
        every MISS row (hit rows are zero — the serve engine reads hits from
        the device cache store and only ships misses over the link), and the
        per-id fetch-success mask. ``ok`` is False for a miss whose fetch
        exhausted its retries or the ``deadline_s`` virtual budget (shared
        across all misses of this call); such a miss stays OUT of the pending
        set — its payload row is zero, the caller must mask the cluster out
        of this step's attend, and a later window refetches it. Records
        hit/miss/pending traffic; cache *insertion* stays deferred.
        """
        cluster_ids = np.asarray(cluster_ids, dtype=np.int64)
        n = self.kv_host.shape[0]
        if len(cluster_ids):
            bad = (cluster_ids < 0) | (cluster_ids >= n)
            if bad.any():
                raise ValueError(
                    f"cluster_ids out of range for a store of {n} clusters: "
                    f"{np.unique(cluster_ids[bad])[:8].tolist()}")
        slot, _ = self.table.lookup(cluster_ids)
        hit = slot >= 0
        self.tick += 1
        self.stats.lookups += len(cluster_ids)
        self.stats.hits += int(hit.sum())
        self.stats.misses += int((~hit).sum())
        self.stats.bytes_from_cache += int(hit.sum()) * self.bytes_per_cluster
        if hit.any():
            self.stamp[slot[hit]] = self.tick            # touch (cheap, vector)
            self.ref_bit[slot[hit]] = True

        miss_payload = np.zeros((len(cluster_ids),) + self.kv_host.shape[1:],
                                dtype=self.kv_host.dtype)
        ok = np.ones(len(cluster_ids), dtype=bool)
        # A cluster missed again before the deferred update lands is served
        # from the pending set: one link transfer per cluster per update
        # window, not one per lookup (previously double-fetched AND
        # double-counted in bytes_over_link).
        if (~hit).any():
            fresh_ids: List[int] = []
            elapsed = 0.0                       # virtual clock, per call
            for pos in np.where(~hit)[0]:
                cid = int(cluster_ids[pos])
                block = self._pending_map.get(cid)
                if block is None:
                    budget = None if deadline_s is None else deadline_s - elapsed
                    block, spent = self._fetch(cid, budget)
                    elapsed += spent
                    if block is None:           # failed: stays out of the
                        ok[pos] = False         # pending set -> refetched in
                        self.stats.failed_fetches += 1   # a later window
                        continue
                    self._pending_map[cid] = block
                    fresh_ids.append(cid)
                    self.stats.bytes_over_link += self.bytes_per_cluster
                else:
                    self.stats.pending_hits += 1
                    self.stats.bytes_from_pending += self.bytes_per_cluster
                miss_payload[pos] = block
            # defer admission of fresh misses (paper: async update by CPU pool)
            if fresh_ids and not self.passthrough:
                self._pending.append((
                    np.asarray(fresh_ids, dtype=np.int64),
                    np.stack([self._pending_map[c] for c in fresh_ids])))
                self.stats.updates_deferred += 1
        return slot, hit, miss_payload, ok

    def assemble(self, cluster_ids: np.ndarray,
                 steady_payload: Optional[np.ndarray] = None) -> np.ndarray:
        """Assemble the execution buffer for one decode step (synchronous).

        Returns the concatenated payloads [steady | retrieved clusters] and
        records hit/miss traffic. Cache *insertion* is deferred (async update).
        """
        slot, hit, payload, _ = self.translate(cluster_ids)
        if hit.any():
            payload[hit] = self.cache[slot[hit]]
        if steady_payload is not None:
            self.stats.bytes_steady += int(steady_payload.nbytes)
            return np.concatenate([steady_payload, payload], axis=0)
        return payload

    # ------------------------------------------------------------------ update
    def apply_updates(self) -> List[Tuple[np.ndarray, np.ndarray, np.ndarray]]:
        """Apply deferred admissions (runs off the critical path).

        Returns the applied admissions as ``(slots, cluster_ids, payload)``
        triples so a caller that mirrors this cache in device memory (the
        serve engine's block-cache store) can replay the same scatter.
        """
        admissions: List[Tuple[np.ndarray, np.ndarray, np.ndarray]] = []
        for ids, payload in self._pending:
            adm = self._admit(ids, payload)
            if adm is not None:
                admissions.append(adm)
        self._pending.clear()
        self._pending_map.clear()
        return admissions

    def _victims(self, n: int) -> np.ndarray:
        if self.policy == "lru":
            return np.argsort(self.stamp)[:n]
        if self.policy == "fifo":
            v = (self.clock_hand + np.arange(n)) % len(self.cache_owner)
            self.clock_hand = int((self.clock_hand + n) % len(self.cache_owner))
            return v
        # clock (second chance) — victims must be unique within a batch
        victims: list = []
        chosen = set()
        guard = 0
        size = len(self.cache_owner)
        while len(victims) < n and guard < 4 * size:
            h = self.clock_hand
            self.clock_hand = (h + 1) % size
            guard += 1
            if h in chosen:
                continue
            if self.ref_bit[h]:
                self.ref_bit[h] = False
            else:
                victims.append(h)
                chosen.add(h)
        for h in range(size):                      # exhaustive fallback
            if len(victims) >= n:
                break
            if h not in chosen:
                victims.append(h)
                chosen.add(h)
        return np.asarray(victims, dtype=np.int64)

    def _admit(self, cluster_ids: np.ndarray, payload: np.ndarray
               ) -> Optional[Tuple[np.ndarray, np.ndarray, np.ndarray]]:
        if self.passthrough:
            return None
        # dedupe (a cluster may be requested twice before updates apply) in
        # FIRST-REQUESTED order: np.unique re-sorts by cluster id, so a
        # capacity clip below would drop by id rather than request order —
        # re-sorting the unique indices restores arrival order.
        _, uniq = np.unique(cluster_ids, return_index=True)
        uniq = np.sort(uniq)
        cluster_ids, payload = cluster_ids[uniq], payload[uniq]
        fresh = self.table.cache_slot[cluster_ids] < 0
        cluster_ids, payload = cluster_ids[fresh], payload[fresh]
        if len(cluster_ids) == 0:
            return None
        # one assemble may request more unique clusters than the cache holds
        # (tiny caches / huge retrieval zones): admit only what fits — the
        # overflow stays host-resident and will miss again, which is correct.
        n_cap = len(self.cache_owner)
        if len(cluster_ids) > n_cap:
            cluster_ids, payload = cluster_ids[:n_cap], payload[:n_cap]
        victims = self._victims(len(cluster_ids))
        evicted = self.cache_owner[victims]
        live = evicted >= 0
        self.table.cache_slot[evicted[live]] = -1
        self.cache[victims] = payload
        self.cache_owner[victims] = cluster_ids
        self.table.cache_slot[cluster_ids] = victims
        self.stamp[victims] = self.tick
        self.ref_bit[victims] = True
        return victims, cluster_ids, payload
