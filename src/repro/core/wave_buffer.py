"""Wave buffer — the accuracy-agnostic buffer manager (paper Sec. 4.3).

On a real TPU deployment the KV store lives in sharded HBM and the "cache" is
HBM itself (DESIGN §2). This module implements the paper's *host-offload*
configuration — KV blocks in host memory, a fixed-size device block cache,
an execution buffer assembled from {steady zone, cache hits, misses} — used by
the single-host serving driver and the cache benchmarks. Mirroring the paper:

* cluster -> block indirection via a mapping table (logical clusters may span
  multiple fixed-size physical blocks),
* synchronous cache *access* on the critical path, asynchronous (deferred,
  vectorized) cache *update* — LRU metadata is maintained off the hot path,
* hit/miss/transfer accounting to reproduce Fig. 16-style analyses.

The control plane is NumPy (the paper runs it on CPU threads); the data plane
arrays live wherever the caller puts them (device or host).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np


@dataclass
class BufferStats:
    lookups: int = 0
    hits: int = 0
    misses: int = 0
    bytes_from_cache: int = 0
    bytes_over_link: int = 0        # host->device traffic (the "PCIe" analogue)
    bytes_from_pending: int = 0     # repeat-miss bytes served from the pending set
    bytes_steady: int = 0
    updates_deferred: int = 0
    pending_hits: int = 0           # repeat misses served from the pending set

    @property
    def hit_ratio(self) -> float:
        return self.hits / max(1, self.lookups)

    @property
    def effective_hit_ratio(self) -> float:
        """Fig. 16-style effective hit rate: a pending hit never crosses the
        link again, so for traffic purposes it IS a hit — counting it as a
        plain miss (as ``hit_ratio`` alone would) understates the cache under
        repeat misses within one update window."""
        return (self.hits + self.pending_hits) / max(1, self.lookups)

    def merge(self, other: "BufferStats") -> None:
        """Accumulate another buffer's counters (engine-level aggregation)."""
        for f in ("lookups", "hits", "misses", "bytes_from_cache",
                  "bytes_over_link", "bytes_from_pending", "bytes_steady",
                  "updates_deferred", "pending_hits"):
            setattr(self, f, getattr(self, f) + getattr(other, f))


class ClusterMappingTable:
    """Logical cluster -> physical block address translation (paper Fig. 9).

    Each cluster occupies ``blocks_per_cluster`` consecutive physical blocks in
    host memory; the table tracks, per cluster, the device-cache slot (or -1).
    Implemented as flat int arrays for O(1) vectorized lookup.
    """

    def __init__(self, n_clusters: int, blocks_per_cluster: int):
        self.blocks_per_cluster = blocks_per_cluster
        self.host_block = np.arange(n_clusters, dtype=np.int64) * blocks_per_cluster
        self.cache_slot = np.full(n_clusters, -1, dtype=np.int64)

    def lookup(self, cluster_ids: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """-> (cache_slot per cluster (-1 = miss), host_block per cluster)."""
        return self.cache_slot[cluster_ids], self.host_block[cluster_ids]


class WaveBuffer:
    """Device block cache + execution-buffer assembly with deferred LRU.

    ``kv_host``: (n_clusters, bytes_per_cluster) conceptual host store — here
    an ndarray of cluster payloads (keys+values flattened). The device cache
    holds ``cache_clusters`` payload rows.
    """

    def __init__(self, kv_host: np.ndarray, cache_clusters: int,
                 blocks_per_cluster: int = 1, policy: str = "lru"):
        assert policy in ("lru", "fifo", "clock")
        if cache_clusters < 0:
            raise ValueError(f"cache_clusters must be >= 0, got {cache_clusters}")
        # cache_clusters == 0 (tiny int(frac * n) configs round to zero) is an
        # explicit PASS-THROUGH: every lookup is a miss served over the link
        # (with pending-set dedup within an update window) and nothing is ever
        # admitted — not an accident of the _admit early-return path.
        self.passthrough = cache_clusters == 0
        self.kv_host = kv_host
        n = kv_host.shape[0]
        self.table = ClusterMappingTable(n, blocks_per_cluster)
        self.cache = np.zeros((cache_clusters,) + kv_host.shape[1:],
                              dtype=kv_host.dtype)
        self.cache_owner = np.full(cache_clusters, -1, dtype=np.int64)
        self.policy = policy
        self.clock_hand = 0
        self.ref_bit = np.zeros(cache_clusters, dtype=bool)
        self.stamp = np.zeros(cache_clusters, dtype=np.int64)   # LRU timestamps
        self.tick = 0
        self.stats = BufferStats()
        self._pending: List[Tuple[np.ndarray, np.ndarray]] = []
        self._pending_map: Dict[int, np.ndarray] = {}   # id -> fetched payload
        self.bytes_per_cluster = int(kv_host[0].nbytes) if n else 0

    # ------------------------------------------------------------------ access
    def translate(self, cluster_ids: np.ndarray
                  ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Control-plane access for one decode step (synchronous).

        Returns ``(slot, hit, miss_payload)``: per-id device-cache slot
        (>= 0 for hits, -1 for misses), the hit mask, and the host payload of
        every MISS row (hit rows are zero — the serve engine reads hits from
        the device cache store and only ships misses over the link). Records
        hit/miss/pending traffic; cache *insertion* stays deferred.
        """
        cluster_ids = np.asarray(cluster_ids, dtype=np.int64)
        slot, _ = self.table.lookup(cluster_ids)
        hit = slot >= 0
        self.tick += 1
        self.stats.lookups += len(cluster_ids)
        self.stats.hits += int(hit.sum())
        self.stats.misses += int((~hit).sum())
        self.stats.bytes_from_cache += int(hit.sum()) * self.bytes_per_cluster
        if hit.any():
            self.stamp[slot[hit]] = self.tick            # touch (cheap, vector)
            self.ref_bit[slot[hit]] = True

        miss_payload = np.zeros((len(cluster_ids),) + self.kv_host.shape[1:],
                                dtype=self.kv_host.dtype)
        # A cluster missed again before the deferred update lands is served
        # from the pending set: one link transfer per cluster per update
        # window, not one per lookup (previously double-fetched AND
        # double-counted in bytes_over_link).
        if (~hit).any():
            fresh_ids: List[int] = []
            for pos in np.where(~hit)[0]:
                cid = int(cluster_ids[pos])
                block = self._pending_map.get(cid)
                if block is None:
                    block = self.kv_host[cid]
                    self._pending_map[cid] = block
                    fresh_ids.append(cid)
                    self.stats.bytes_over_link += self.bytes_per_cluster
                else:
                    self.stats.pending_hits += 1
                    self.stats.bytes_from_pending += self.bytes_per_cluster
                miss_payload[pos] = block
            # defer admission of fresh misses (paper: async update by CPU pool)
            if fresh_ids and not self.passthrough:
                self._pending.append((
                    np.asarray(fresh_ids, dtype=np.int64),
                    np.stack([self._pending_map[c] for c in fresh_ids])))
                self.stats.updates_deferred += 1
        return slot, hit, miss_payload

    def assemble(self, cluster_ids: np.ndarray,
                 steady_payload: Optional[np.ndarray] = None) -> np.ndarray:
        """Assemble the execution buffer for one decode step (synchronous).

        Returns the concatenated payloads [steady | retrieved clusters] and
        records hit/miss traffic. Cache *insertion* is deferred (async update).
        """
        slot, hit, payload = self.translate(cluster_ids)
        if hit.any():
            payload[hit] = self.cache[slot[hit]]
        if steady_payload is not None:
            self.stats.bytes_steady += int(steady_payload.nbytes)
            return np.concatenate([steady_payload, payload], axis=0)
        return payload

    # ------------------------------------------------------------------ update
    def apply_updates(self) -> List[Tuple[np.ndarray, np.ndarray, np.ndarray]]:
        """Apply deferred admissions (runs off the critical path).

        Returns the applied admissions as ``(slots, cluster_ids, payload)``
        triples so a caller that mirrors this cache in device memory (the
        serve engine's block-cache store) can replay the same scatter.
        """
        admissions: List[Tuple[np.ndarray, np.ndarray, np.ndarray]] = []
        for ids, payload in self._pending:
            adm = self._admit(ids, payload)
            if adm is not None:
                admissions.append(adm)
        self._pending.clear()
        self._pending_map.clear()
        return admissions

    def _victims(self, n: int) -> np.ndarray:
        if self.policy == "lru":
            return np.argsort(self.stamp)[:n]
        if self.policy == "fifo":
            v = (self.clock_hand + np.arange(n)) % len(self.cache_owner)
            self.clock_hand = int((self.clock_hand + n) % len(self.cache_owner))
            return v
        # clock (second chance) — victims must be unique within a batch
        victims: list = []
        chosen = set()
        guard = 0
        size = len(self.cache_owner)
        while len(victims) < n and guard < 4 * size:
            h = self.clock_hand
            self.clock_hand = (h + 1) % size
            guard += 1
            if h in chosen:
                continue
            if self.ref_bit[h]:
                self.ref_bit[h] = False
            else:
                victims.append(h)
                chosen.add(h)
        for h in range(size):                      # exhaustive fallback
            if len(victims) >= n:
                break
            if h not in chosen:
                victims.append(h)
                chosen.add(h)
        return np.asarray(victims, dtype=np.int64)

    def _admit(self, cluster_ids: np.ndarray, payload: np.ndarray
               ) -> Optional[Tuple[np.ndarray, np.ndarray, np.ndarray]]:
        if self.passthrough:
            return None
        # dedupe (a cluster may be requested twice before updates apply) in
        # FIRST-REQUESTED order: np.unique re-sorts by cluster id, so a
        # capacity clip below would drop by id rather than request order —
        # re-sorting the unique indices restores arrival order.
        _, uniq = np.unique(cluster_ids, return_index=True)
        uniq = np.sort(uniq)
        cluster_ids, payload = cluster_ids[uniq], payload[uniq]
        fresh = self.table.cache_slot[cluster_ids] < 0
        cluster_ids, payload = cluster_ids[fresh], payload[fresh]
        if len(cluster_ids) == 0:
            return None
        # one assemble may request more unique clusters than the cache holds
        # (tiny caches / huge retrieval zones): admit only what fits — the
        # overflow stays host-resident and will miss again, which is correct.
        n_cap = len(self.cache_owner)
        if len(cluster_ids) > n_cap:
            cluster_ids, payload = cluster_ids[:n_cap], payload[:n_cap]
        victims = self._victims(len(cluster_ids))
        evicted = self.cache_owner[victims]
        live = evicted >= 0
        self.table.cache_slot[evicted[live]] = -1
        self.cache[victims] = payload
        self.cache_owner[victims] = cluster_ids
        self.table.cache_slot[cluster_ids] = victims
        self.stamp[victims] = self.tick
        self.ref_bit[victims] = True
        return victims, cluster_ids, payload
