"""Tripartite wave attention (paper Sec. 4.2) — decode-step attention.

o = merge(o_steady, o_retrieval, o_estimation)

* steady zone: sinks + local window, exact.
* retrieval zone: top-r clusters by q·centroid, KV blocks gathered, exact.
* estimation zone: next-e clusters, contribution ã_i·VS_i with
  ã_i = exp(q·C_i/√d)/Z and Z accumulating s_i·exp(q·C_i/√d) — the Jensen
  lower bound (Eq. 2–4).

GQA: clusters belong to kv heads; the retrieval decision is shared across a
kv head's query group (group-max centroid score), estimation stays per-query.

This module is the pure-jnp reference path; ``repro.kernels.wave_attention``
provides the fused Pallas kernel with identical semantics.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import RetroConfig
from repro.core.wave_index import WaveState
from repro.core.zones import ZonePlan
from repro.models.layers import soft_cap

NEG = -1e30


class WaveAttnOut(NamedTuple):
    out: jax.Array           # (B, Hq, hd)
    retrieved: jax.Array     # (B, Hkv, r) int32 cluster ids (for cache stats)


def rank_clusters(q_group: jax.Array, state: WaveState, plan: ZonePlan,
                  window: Optional[jax.Array] = None,
                  softcap: Optional[float] = None, cluster_offset=0):
    """Rank clusters by centroid score.

    q_group: (B, Hkv, G, hd). Returns (cscore (B,Hkv,G,M) f32, idx_re (B,Hkv,r+e)).
    ``cluster_offset`` is the global index of local cluster 0 (sharded
    retrieval: each shard holds an M/n slice of the cluster axis).
    """
    hd = q_group.shape[-1]
    scale = 1.0 / math.sqrt(hd)
    cs = jnp.einsum("bhgd,bhmd->bhgm", q_group.astype(jnp.float32),
                    state.centroid) * scale
    cs = soft_cap(cs, softcap)
    M = state.centroid.shape[2]
    # per-row active range + dead clusters (size 0: ragged-padding artefacts)
    in_range = jnp.arange(M)[None, :] + cluster_offset \
        < state.n_clusters[:, None]                       # (B, M)
    valid = in_range[:, None, :] & (state.size > 0)       # (B, Hkv, M)
    if window is not None:
        q_pos = state.length - 1                          # (B,)
        valid = valid & (state.max_pos > q_pos[:, None, None] - window)
    cs = jnp.where(valid[:, :, None, :], cs, NEG)
    group_score = jnp.max(cs, axis=2)                     # (B, Hkv, M)
    _, idx_re = jax.lax.top_k(group_score, plan.r + plan.e)
    return cs, idx_re


def _gather_clusters(state: WaveState, idx: jax.Array):
    """Gather cluster blocks. idx: (B, Hkv, r) -> stores (B, Hkv, r, cap, hd)."""
    def take(a):
        return jnp.take_along_axis(
            a, idx.reshape(idx.shape + (1,) * (a.ndim - 3)), axis=2)
    return (take(state.k_store), take(state.v_store), take(state.pos_store))


def _estimation_zone(state: WaveState, cs, idx_r, idx_e, *,
                     use_estimation: bool, overflow_correction: bool):
    """Estimation-zone inputs for the fused merge (shared by every impl).

    cs: (B, H, G, M) centroid scores; idx_r/idx_e: (B, H, r/e) cluster ids.
    Returns (est_logit, cs_e (B, H, G, E), vs_e (B, H, E, hd)) — all O(meta
    index)-sized: the only cluster-store-sized tensors of the decode step are
    the stores themselves.
    """
    B, Hkv = cs.shape[:2]
    hd = state.vsum.shape[-1]
    e = idx_e.shape[2]
    if use_estimation and e > 0:
        cs_e = jnp.take_along_axis(cs, idx_e[:, :, None, :], axis=3)   # (B,H,G,e)
        sz_e = jnp.take_along_axis(state.size, idx_e, axis=2)          # (B,H,e)
        vs_e = jnp.take_along_axis(
            state.vsum, idx_e[..., None], axis=2)                      # (B,H,e,hd)
        log_sz = jnp.log(jnp.maximum(sz_e.astype(jnp.float32), 1.0))
        est_logit = cs_e + log_sz[:, :, None, :]                       # s_i·exp(cs)
        est_valid = sz_e > 0
        est_logit = jnp.where(est_valid[:, :, None, :], est_logit, NEG)
    else:
        est_logit = jnp.full((B, Hkv, cs.shape[2], 1), NEG, jnp.float32)
        cs_e = est_logit
        vs_e = jnp.zeros((B, Hkv, 1, hd), jnp.float32)

    # overflow correction: tokens dropped from retrieved stores (size > cap)
    # re-enter through their cluster's estimate, scaled by the dropped fraction.
    if overflow_correction and use_estimation and idx_r.shape[2] > 0:
        cs_r = jnp.take_along_axis(cs, idx_r[:, :, None, :], axis=3)   # (B,H,G,r)
        sz_r = jnp.take_along_axis(state.size, idx_r, axis=2)
        st_r = jnp.take_along_axis(state.stored, idx_r, axis=2)
        vs_r = jnp.take_along_axis(state.vsum, idx_r[..., None], axis=2)
        over = jnp.maximum(sz_r - st_r, 0).astype(jnp.float32)         # (B,H,r)
        frac = over / jnp.maximum(sz_r.astype(jnp.float32), 1.0)
        log_over = jnp.where(over > 0, jnp.log(jnp.maximum(over, 1.0)), NEG)
        ov_logit = cs_r + log_over[:, :, None, :]
        est_logit = jnp.concatenate([est_logit, ov_logit], axis=3)
        cs_e = jnp.concatenate([cs_e, cs_r], axis=3)
        vs_e = jnp.concatenate([vs_e, vs_r * frac[..., None]], axis=2)
    return est_logit, cs_e, vs_e


def _retrieval_cover(state: WaveState, cs, idx_r):
    """Estimation-zone COVER for the retrieved clusters (degraded decode).

    For each retrieved cluster, the Jensen estimate of its STORED tokens:
    ``cov_logit = cs + log(stored_eff)``, ``cov_vs = vsum * stored_frac``
    (the overflow fraction is excluded — the unconditional overflow entry of
    :func:`_estimation_zone` already covers it, so cover + overflow together
    equal the full-cluster estimate with no double count). The attend path
    enables a cluster's cover entry only when its validity mask is 0 — a
    fetch that missed its deadline loses exact attention for the step but
    keeps its estimated attention mass (paper Eq. 2-4 accuracy bound).

    Touches only the META index, like the rest of the rank half. Dead or
    empty clusters get ``cov_logit = NEG`` exactly (inert in every merge
    impl). Returns ``(cov_logit (B,H,G,r), cs_r (B,H,G,r), cov_vs
    (B,H,r,hd))``.
    """
    cs_r = jnp.take_along_axis(cs, idx_r[:, :, None, :], axis=3)   # (B,H,G,r)
    sz_r = jnp.take_along_axis(state.size, idx_r, axis=2)          # (B,H,r)
    st_r = jnp.take_along_axis(state.stored, idx_r, axis=2)
    vs_r = jnp.take_along_axis(state.vsum, idx_r[..., None], axis=2)
    over = jnp.maximum(sz_r - st_r, 0).astype(jnp.float32)         # (B,H,r)
    st_eff = sz_r.astype(jnp.float32) - over                       # stored part
    frac = st_eff / jnp.maximum(sz_r.astype(jnp.float32), 1.0)
    log_st = jnp.where(st_eff > 0, jnp.log(jnp.maximum(st_eff, 1.0)), NEG)
    cov_logit = jnp.where(st_eff[:, :, None, :] > 0,
                          cs_r + log_st[:, :, None, :], NEG)
    cov_vs = vs_r * frac[..., None]
    return cov_logit, cs_r, cov_vs


ATTN_IMPLS = ("jnp", "fused", "pallas")


def resolve_attn_impl(impl: Optional[str]) -> str:
    """Normalize an attention-impl selection. ``None`` -> "jnp"; "fused"
    (paged gather-free kernel) auto-resolves to the interpretable path on CPU
    inside the kernel wrapper; "pallas" is the legacy gathered-buffer kernel."""
    impl = impl or "jnp"
    if impl not in ATTN_IMPLS:
        raise ValueError(f"unknown attn impl {impl!r}; expected {ATTN_IMPLS}")
    return impl


def _local_positions(state: WaveState):
    """Absolute position of every local-buffer slot, -1 for empty. (B, lbuf)."""
    lbuf = state.local_k.shape[2]
    l0 = state.length - state.local_len              # (B,) abs pos of buffer[0]
    local_pos = l0[:, None] + jnp.arange(lbuf, dtype=jnp.int32)[None, :]
    return jnp.where(jnp.arange(lbuf)[None, :] < state.local_len[:, None],
                     local_pos, -1)                  # (B, lbuf)


def _fused_wave_attention(qg, state: WaveState, idx_r, est_logit, cs_e, vs_e,
                          *, window, softcap, kv_src=None, valid=None):
    """Gather-free decode merge: hand the raw zones to the paged Pallas
    kernel (``kernels.wave_attention``), which walks sink -> local buffer ->
    the r retrieved clusters IN PLACE via scalar-prefetched ids and folds the
    estimation zone into the same online softmax. No (B, H, r, cap, hd)
    gather temp, no execution-buffer concat.

    ``kv_src``: optional ``(k_blocks, v_blocks, pos_blocks)`` replacing the
    state's monolithic cluster stores as the block source — the cache-slot
    indirection hook of the host-offload serve path, where ``idx_r`` holds
    device-cache slots (hits + per-step miss staging slots) instead of
    cluster ids. Block payloads are bit-identical either way, so placement
    never changes the result."""
    from repro.kernels.wave_attention import ops as wa_ops
    B, Hkv, G, hd = qg.shape
    r = idx_r.shape[2]
    k_blk, v_blk, p_blk = kv_src if kv_src is not None else (
        state.k_store, state.v_store, state.pos_store)
    q_pos = state.length - 1                                   # (B,)

    # per-row validity bounds: pos <= hi (= q_pos) and pos > lo. ``lo`` folds
    # the sliding window: for integer positions p, p > q_pos - window (the
    # f32 comparison of the jnp path) <=> p > floor(q_pos - window).
    hi = q_pos.astype(jnp.int32)
    if window is None:
        lo = jnp.full_like(hi, -1)
    else:
        lo = jnp.floor(q_pos.astype(jnp.float32)
                       - jnp.asarray(window, jnp.float32)).astype(jnp.int32)
        lo = jnp.maximum(lo, -1)
    rowb = jnp.broadcast_to(
        jnp.stack([lo, hi], axis=-1)[:, None, :], (B, Hkv, 2))

    local_pos = jnp.broadcast_to(_local_positions(state)[:, None, :],
                                 (B, Hkv, state.local_k.shape[2]))
    if r == 0:            # steady-zone-only plan: pad one dead retrieval slot
        idx_k = jnp.zeros((B, Hkv, 1), jnp.int32)
        live = jnp.zeros((B, Hkv, 1), jnp.int32)
    else:
        idx_k = idx_r
        # degraded decode: the per-cluster validity mask rides the kernel's
        # existing ``live`` operand — an invalid (fetch-failed) cluster is
        # skipped by the paged walk exactly like a dead padding slot.
        live = (valid.astype(jnp.int32) if valid is not None
                else jnp.ones((B, Hkv, r), jnp.int32))

    return wa_ops.paged_wave_attention(
        qg, state.sink_k, state.sink_v, state.local_k, state.local_v,
        local_pos, k_blk, v_blk, p_blk, idx_k,
        live, rowb, est_logit, cs_e, vs_e, softcap=softcap,
        interpret=wa_ops.on_cpu())


def wave_attention_decode(q: jax.Array, state: WaveState, retro: RetroConfig,
                          plan: ZonePlan, *, window: Optional[jax.Array] = None,
                          softcap: Optional[float] = None,
                          use_estimation: bool = True,
                          overflow_correction: bool = True,
                          impl: str = "jnp", cluster_offset=0,
                          include_steady=True,
                          return_parts: bool = False) -> WaveAttnOut:
    """One decode step of tripartite attention.

    q: (B, Hq, hd) — query at position state.length - 1 (the current token's
    K/V must already be appended to the local buffer).

    ``impl``: "jnp" (reference execution-buffer path), "fused" (gather-free
    paged Pallas kernel — zones read in place, interpret mode on CPU), or
    "pallas" (legacy gathered-buffer kernel). ``return_parts`` and sharded
    retrieval always use the reference path.

    Sharded-retrieval hooks (core.distributed): ``cluster_offset`` maps local
    cluster ids to global for validity; ``include_steady`` (may be traced)
    gates the steady zone so exactly one shard contributes it;
    ``return_parts`` yields the unnormalized (num, den, m, idx_r) for a
    cross-shard LSE merge.
    """
    B, Hq, hd = q.shape
    Hkv = state.centroid.shape[1]
    G = Hq // Hkv
    qg = q.reshape(B, Hkv, G, hd)
    impl = resolve_attn_impl(impl)

    idx_r, est_logit, cs_e, vs_e = wave_decode_rank(
        qg, state, retro, plan, window=window, softcap=softcap,
        use_estimation=use_estimation,
        overflow_correction=overflow_correction,
        cluster_offset=cluster_offset)
    return wave_attention_attend(
        q, state, retro, plan, idx_r, est_logit, cs_e, vs_e, window=window,
        softcap=softcap, impl=impl, include_steady=include_steady,
        return_parts=return_parts)


def wave_decode_rank(qg, state: WaveState, retro: RetroConfig, plan: ZonePlan,
                     *, window: Optional[jax.Array] = None,
                     softcap: Optional[float] = None,
                     use_estimation: bool = True,
                     overflow_correction: bool = True, cluster_offset=0,
                     with_cover: bool = False):
    """Control-plane half of the decode step: rank clusters and build the
    estimation-zone inputs. Touches only the META index (centroids, value
    sums, sizes) and per-row counters — never the cluster payload stores —
    so the host-offload serve path can run it with the payload stores absent,
    translate ``idx_r`` through its ``ClusterMappingTable``, and hand cache
    slots to :func:`wave_attention_attend`.

    qg: (B, Hkv, G, hd). Returns (idx_r, est_logit, cs_e, vs_e); with
    ``with_cover`` additionally the :func:`_retrieval_cover` triple the
    attend half needs to estimation-cover fetch-failed clusters (degraded
    decode) — computed here because the attend half of the offload path has
    no access to the meta index."""
    cs, idx_re = rank_clusters(qg, state, plan, window, softcap,
                               cluster_offset)
    idx_r, idx_e = idx_re[:, :, :plan.r], idx_re[:, :, plan.r:]
    est_logit, cs_e, vs_e = _estimation_zone(
        state, cs, idx_r, idx_e, use_estimation=use_estimation,
        overflow_correction=overflow_correction)
    if with_cover:
        cover = _retrieval_cover(state, cs, idx_r)
        return idx_r, est_logit, cs_e, vs_e, cover
    return idx_r, est_logit, cs_e, vs_e


def wave_attention_attend(q, state: WaveState, retro: RetroConfig,
                          plan: ZonePlan, idx, est_logit, cs_e, vs_e, *,
                          kv_src=None, window: Optional[jax.Array] = None,
                          softcap: Optional[float] = None, impl: str = "jnp",
                          include_steady=True, return_parts: bool = False,
                          valid=None, cover=None):
    """Data-plane half of the decode step: exact attention over the steady
    zone plus the ``idx``-addressed blocks of ``kv_src``, merged with the
    estimation zone.

    ``kv_src``: optional ``(k_blocks, v_blocks, pos_blocks)`` with leading
    dims (B, Hkv, N_slots, ...) replacing the state's monolithic cluster
    stores as the block source. This is the cache-slot indirection of the
    host-offload configuration: ``idx`` then holds device-cache slots
    (cache hits + per-step miss staging slots) translated on the control
    plane, not cluster ids. Block payloads are identical bits either way, so
    cache placement is accuracy-agnostic.

    ``valid``: optional (B, Hkv, r) per-cluster validity mask (degraded
    decode): a 0 cluster is masked OUT of the retrieval zone — its blocks
    never fetched in time — and, when ``cover`` (the
    :func:`_retrieval_cover` triple from ``wave_decode_rank(...,
    with_cover=True)``) is given, its attention mass re-enters through the
    estimation zone. With an all-ones mask the cover entries are NEG/zero
    gated and the result is bit-identical to ``valid=None``."""
    B, Hq, hd = q.shape
    Hkv = state.centroid.shape[1]
    G = Hq // Hkv
    r = idx.shape[2]
    q_pos = state.length - 1                               # (B,) per-row
    qg = q.reshape(B, Hkv, G, hd)
    impl = resolve_attn_impl(impl)

    # ---- degraded decode: estimation-cover the masked-out clusters ---------
    # A valid cluster's cover entry is gated to (NEG logit, zero vsum): it
    # contributes exactly 0.0 to num/den and cannot move the softmax max, so
    # all-valid steps are bit-identical with or without the cover concat.
    if valid is not None and cover is not None and r > 0:
        v_ok = valid > 0                                   # (B, Hkv, r)
        cov_logit, cov_cs, cov_vs = cover
        cov_logit = jnp.where(v_ok[:, :, None, :], NEG, cov_logit)
        cov_vs = jnp.where(v_ok[..., None], 0.0, cov_vs)
        est_logit = jnp.concatenate([est_logit, cov_logit], axis=3)
        cs_e = jnp.concatenate([cs_e, cov_cs], axis=3)
        vs_e = jnp.concatenate([vs_e, cov_vs], axis=2)

    # ---- gather-free paged kernel: zones handed over unconcatenated --------
    # (the sharded return_parts merge keeps the reference path: partial
    # (num, den, m) are what shards LSE-combine, see core.distributed)
    if impl == "fused" and not return_parts and include_steady is True:
        out = _fused_wave_attention(qg, state, idx, est_logit, cs_e, vs_e,
                                    window=window, softcap=softcap,
                                    kv_src=kv_src, valid=valid)
        return WaveAttnOut(out.reshape(B, Hq, hd).astype(q.dtype), idx)

    # ---- execution buffer: steady zone + retrieved blocks ------------------
    if kv_src is None:
        kb, vb, pb = _gather_clusters(state, idx)          # (B,H,r,cap,hd)
    else:
        k_blk, v_blk, p_blk = kv_src
        take = lambda a: jnp.take_along_axis(
            a, idx.reshape(idx.shape + (1,) * (a.ndim - 3)), axis=2)
        kb, vb, pb = take(k_blk), take(v_blk), take(p_blk)
    cap = kb.shape[3]
    k_ret = kb.reshape(B, Hkv, r * cap, hd)
    v_ret = vb.reshape(B, Hkv, r * cap, hd)
    p_ret = pb.reshape(B, Hkv, r * cap)

    sink_pos = jnp.broadcast_to(jnp.arange(retro.sink, dtype=jnp.int32),
                                (B, Hkv, retro.sink))
    lbuf = state.local_k.shape[2]
    local_pos = jnp.broadcast_to(_local_positions(state)[:, None, :],
                                 (B, Hkv, lbuf))

    k_exec = jnp.concatenate([state.sink_k, state.local_k, k_ret], axis=2)
    v_exec = jnp.concatenate([state.sink_v, state.local_v, v_ret], axis=2)
    p_exec = jnp.concatenate([sink_pos, local_pos, p_ret], axis=2)

    # ---- validity mask over the execution buffer (per-row q_pos) -----------
    qp = q_pos[:, None, None]
    ok = (p_exec >= 0) & (p_exec <= qp)
    if window is not None:
        ok = ok & (p_exec > qp - window)
    if valid is not None and r > 0:        # degraded decode: mask failed
        ret_ok = jnp.repeat(valid > 0, cap, axis=2)        # (B,Hkv,r·cap)
        n_steady = p_exec.shape[2] - r * cap
        ok = ok & jnp.concatenate(
            [jnp.ones((B, Hkv, n_steady), bool), ret_ok], axis=2)
    if include_steady is not True:                 # traced gate (sharding)
        n_steady = retro.sink + lbuf
        is_steady = jnp.arange(p_exec.shape[2]) < n_steady
        ok = ok & (jnp.asarray(include_steady) | ~is_steady)

    if return_parts:
        num, den, m = tripartite_merge_parts_jnp(
            qg, k_exec, v_exec, ok, est_logit, cs_e, vs_e, softcap=softcap)
        return num, den, m, idx
    out = tripartite_merge(qg, k_exec, v_exec, ok, est_logit, cs_e, vs_e,
                           softcap=softcap, impl=impl)
    return WaveAttnOut(out.reshape(B, Hq, hd).astype(q.dtype), idx)


def tripartite_merge_parts_jnp(qg, k_exec, v_exec, valid, est_logit, cs_e,
                               vs_e, *, softcap: Optional[float] = None):
    """Unnormalized fused merge: returns (num (B,H,G,hd), den (B,H,G),
    m (B,H,G)) with num/den scaled by exp(-m). Distribution-friendly: partial
    results from shards LSE-combine via pmax/psum (core.distributed)."""
    hd = qg.shape[-1]
    scale = 1.0 / math.sqrt(hd)
    # keep K/V operands in their storage dtype (bf16) with f32 ACCUMULATION:
    # an explicit .astype(f32) gets hoisted through the gather by XLA and
    # converts the ENTIRE cluster store every step (§Perf iteration, ~2x the
    # store in temps + bytes). MXU takes bf16 natively; accumulate in f32.
    s = jnp.einsum("bhgd,bhtd->bhgt", qg.astype(k_exec.dtype), k_exec,
                   preferred_element_type=jnp.float32) * scale
    s = soft_cap(s, softcap)
    s = jnp.where(valid[:, :, None, :], s, NEG)

    m = jnp.maximum(jnp.max(s, axis=-1), jnp.max(est_logit, axis=-1))  # (B,H,G)
    m = jnp.maximum(m, -1e20)
    p = jnp.exp(s - m[..., None])
    den = jnp.sum(p, axis=-1)
    num = jnp.einsum("bhgt,bhtd->bhgd", p.astype(v_exec.dtype), v_exec,
                     preferred_element_type=jnp.float32)

    live = est_logit > NEG / 2
    w_den = jnp.where(live, jnp.exp(est_logit - m[..., None]), 0.0)    # s_i·e^{cs}
    w_num = jnp.where(live, jnp.exp(cs_e - m[..., None]), 0.0)         # e^{cs}
    den = den + jnp.sum(w_den, axis=-1)
    num = num + jnp.einsum("bhge,bhed->bhgd", w_num, vs_e.astype(jnp.float32))
    return num, den, m


def tripartite_merge_jnp(qg, k_exec, v_exec, valid, est_logit, cs_e, vs_e, *,
                         softcap: Optional[float] = None) -> jax.Array:
    """Reference fused exact-attention + estimation merge.

    qg: (B,H,G,hd); k_exec/v_exec: (B,H,T,hd); valid: (B,H,T) bool;
    est_logit/cs_e: (B,H,G,E) f32 (NEG-masked); vs_e: (B,H,E,hd) f32.
    Returns (B,H,G,hd) f32. The Pallas kernel in
    ``repro.kernels.wave_attention`` implements identical semantics.
    """
    num, den, _ = tripartite_merge_parts_jnp(
        qg, k_exec, v_exec, valid, est_logit, cs_e, vs_e, softcap=softcap)
    return num / jnp.maximum(den, 1e-30)[..., None]


def tripartite_merge(qg, k_exec, v_exec, valid, est_logit, cs_e, vs_e, *,
                     softcap: Optional[float] = None, impl: str = "jnp"):
    if impl == "jnp":
        return tripartite_merge_jnp(qg, k_exec, v_exec, valid, est_logit,
                                    cs_e, vs_e, softcap=softcap)
    from repro.kernels.wave_attention import ops as wa_ops
    return wa_ops.wave_attention_merge(qg, k_exec, v_exec, valid, est_logit,
                                       cs_e, vs_e, softcap=softcap,
                                       interpret=wa_ops.on_cpu())


# ---------------------------------------------------------------------------
# Dense full-attention decode baseline (paper's "full attention" comparator)
# ---------------------------------------------------------------------------

class DenseCache(NamedTuple):
    k: jax.Array            # (B, H, S_max, hd)
    v: jax.Array            # (B, H, S_max, hd)
    length: jax.Array       # (B,) int32 — valid prefix per row


def init_dense_cache(B, H, S_max, hd, dtype=jnp.bfloat16) -> DenseCache:
    return DenseCache(jnp.zeros((B, H, S_max, hd), dtype),
                      jnp.zeros((B, H, S_max, hd), dtype),
                      jnp.zeros((B,), jnp.int32))


def dense_cache_append(cache: DenseCache, k_new, v_new,
                       active: Optional[jax.Array] = None) -> DenseCache:
    """Append (B, H, hd) K/V at each row's own cursor. ``active``: optional
    (B,) bool — inactive rows (free continuous-batching slots) are untouched.
    Right-padded ragged prefills stay correct: appends overwrite the pad slots
    just past each row's true length, so ``pos < length`` only ever admits
    real tokens.

    The mask is applied to the per-row write CURSOR, not the cache: an
    inactive row routes its write out of range, which the dropped scatter
    discards — O(token) per step, in place on the donated cache. The previous
    ``jnp.where(active, new, cache)`` select read AND wrote the full cache
    every step (§Perf: asserted via cost_analysis in tests). A row at
    capacity likewise drops the append instead of clobbering its last slot —
    and its cursor stays put, so ``length`` never claims tokens the cache
    doesn't hold.
    """
    S_max = cache.k.shape[2]
    idx = cache.length
    step = jnp.ones_like(cache.length)
    if active is not None:
        act = jnp.asarray(active)
        idx = jnp.where(act, idx, S_max)       # out of range => dropped write
        step = act.astype(cache.length.dtype)
    step = jnp.where(cache.length < S_max, step, 0)

    def row(buf, new, i):
        return buf.at[:, i].set(new.astype(buf.dtype), mode="drop")

    new_k = jax.vmap(row)(cache.k, k_new, idx)
    new_v = jax.vmap(row)(cache.v, v_new, idx)
    return DenseCache(new_k, new_v, cache.length + step)


def full_attention_decode(q, cache: DenseCache, *, window=None, softcap=None):
    """q: (B, Hq, hd) vs the dense cache. Exact softmax over valid positions
    (per-row lengths)."""
    B, Hq, hd = q.shape
    Hkv = cache.k.shape[1]
    G = Hq // Hkv
    scale = 1.0 / math.sqrt(hd)
    qg = q.reshape(B, Hkv, G, hd)
    # storage-dtype operands + f32 ACCUMULATION (same contract as the wave
    # merge above): an explicit cache.astype(f32) is hoisted by XLA and
    # rewrites the whole (B,H,S_max,hd) cache every step — RL402.
    s = jnp.einsum("bhgd,bhtd->bhgt", qg.astype(cache.k.dtype), cache.k,
                   preferred_element_type=jnp.float32) * scale
    s = soft_cap(s, softcap)
    pos = jnp.arange(cache.k.shape[2])
    ok = pos[None, :] < cache.length[:, None]              # (B, T)
    if window is not None:
        ok = ok & (pos[None, :] > cache.length[:, None] - 1 - window)
    s = jnp.where(ok[:, None, None, :], s, NEG)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgt,bhtd->bhgd", p.astype(cache.v.dtype), cache.v,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, Hq, hd).astype(q.dtype)
