"""Segmented spherical k-means (paper Sec. 4.2, "segmented clustering").

The input sequence is split into fixed-size segments; spherical k-means runs
*within* each segment independently (RoPE-induced spatial locality makes
global clustering unnecessary — paper Fig. 19b). A mean-centering transform
(All-but-the-top / MagicPIG-inspired) is applied before assignment so that
inner-product clustering tracks attention-score ordering; centroid statistics
(mean key, value sum, size) are computed over the *raw* keys/values so the
Jensen bound of the estimation zone holds exactly.

All functions are single-(batch, head) and vmapped by callers.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp


class ClusterResult(NamedTuple):
    """Fixed-capacity cluster stores for one segment.

    k_store/v_store: (k, cap, hd)   padded member keys/values
    pos_store:       (k, cap) int32 member positions, -1 where padded
    centroid:        (k, hd) f32    mean of ALL assigned raw keys
    vsum:            (k, hd) f32    sum of ALL assigned values
    size:            (k,) int32     total assigned count (incl. overflow)
    stored:          (k,) int32     members physically stored (<= cap)
    max_pos:         (k,) int32     max member position (sliding-window masks)
    """
    k_store: jax.Array
    v_store: jax.Array
    pos_store: jax.Array
    centroid: jax.Array
    vsum: jax.Array
    size: jax.Array
    stored: jax.Array
    max_pos: jax.Array


def _normalize(x, eps=1e-8):
    return x / jnp.maximum(jnp.linalg.norm(x, axis=-1, keepdims=True), eps)


def spherical_kmeans(keys: jax.Array, k: int, iters: int, centering: bool = True,
                     valid=None):
    """keys: (n, hd) -> (assign (n,) int32, centroids_raw (k, hd) f32).

    Spherical: centroids are L2-normalized before the assignment step;
    similarity is the inner product (matches q.K attention scoring).
    Returned centroids are raw (un-normalized) means of assigned keys.

    ``valid``: optional (n,) bool — invalid tokens (padding in a right-padded
    ragged batch) carry zero weight everywhere: they never move a centroid and
    never count toward a mean. They still receive an (irrelevant) assignment.
    """
    n, hd = keys.shape
    kf = keys.astype(jnp.float32)
    if valid is None:
        mu = jnp.mean(kf, axis=0, keepdims=True)
    else:
        w = valid.astype(jnp.float32)[:, None]            # (n, 1)
        mu = jnp.sum(kf * w, axis=0, keepdims=True) / jnp.maximum(
            jnp.sum(w), 1.0)
    x = kf - mu if centering else kf

    # deterministic strided init: every (n//k)-th (centered) key
    stride = max(1, n // k)
    init_idx = jnp.minimum(jnp.arange(k) * stride, n - 1)
    cent = x[init_idx]

    onehot_dtype = jnp.float32

    def step(cent, _):
        cn = _normalize(cent)
        sim = x @ cn.T                                    # (n, k)
        assign = jnp.argmax(sim, axis=-1)
        oh = jax.nn.one_hot(assign, k, dtype=onehot_dtype)  # (n, k)
        if valid is not None:
            oh = oh * valid.astype(onehot_dtype)[:, None]
        counts = jnp.sum(oh, axis=0)                      # (k,)
        sums = oh.T @ x                                   # (k, hd)
        new = jnp.where(counts[:, None] > 0, sums / jnp.maximum(counts[:, None], 1.0), cent)
        return new, None

    cent, _ = jax.lax.scan(step, cent, None, length=iters)
    sim = x @ _normalize(cent).T
    assign = jnp.argmax(sim, axis=-1).astype(jnp.int32)

    # raw-space centroids for the estimation-zone Jensen bound
    oh = jax.nn.one_hot(assign, k, dtype=onehot_dtype)
    if valid is not None:
        oh = oh * valid.astype(onehot_dtype)[:, None]
    counts = jnp.sum(oh, axis=0)
    cent_raw = (oh.T @ kf) / jnp.maximum(counts[:, None], 1.0)
    return assign, cent_raw


def build_cluster_stores(keys, values, positions, assign, k: int, cap: int,
                         valid=None) -> ClusterResult:
    """Scatter tokens of one segment into fixed-capacity cluster stores.

    keys/values: (n, hd); positions: (n,) int32; assign: (n,) int32 in [0, k).
    Tokens beyond a cluster's capacity are dropped from the store but still
    counted in centroid/vsum/size — the estimation zone covers them (DESIGN §2).

    ``valid``: optional (n,) bool — invalid (padding) tokens are excluded from
    every store and every statistic; a fully-invalid cluster ends up with
    size 0 / max_pos -1 and is masked out of ranking and estimation.
    """
    n, hd = keys.shape
    kf = keys.astype(jnp.float32)
    vf = values.astype(jnp.float32)

    if valid is not None:
        # out-of-range assignment => zero one-hot row AND dropped scatter write
        assign = jnp.where(valid, assign, k)

    oh = jax.nn.one_hot(assign, k, dtype=jnp.float32)
    size = jnp.sum(oh, axis=0).astype(jnp.int32)
    centroid = (oh.T @ kf) / jnp.maximum(size[:, None].astype(jnp.float32), 1.0)
    vsum = oh.T @ vf
    max_pos = jnp.max(jnp.where(oh.T > 0, positions[None, :], -1), axis=-1).astype(jnp.int32)

    # rank of each token within its cluster (stable grouping via sort)
    order = jnp.argsort(assign, stable=True)              # token ids grouped by cluster
    sa = assign[order]
    starts = jnp.searchsorted(sa, jnp.arange(k), side="left")
    rank = jnp.arange(n) - starts[sa]                     # 0-based rank in cluster

    k_store = jnp.zeros((k, cap, hd), dtype=keys.dtype)
    v_store = jnp.zeros((k, cap, hd), dtype=values.dtype)
    pos_store = jnp.full((k, cap), -1, dtype=jnp.int32)
    # mode="drop" discards rank >= cap writes (overflow)
    k_store = k_store.at[sa, rank].set(keys[order], mode="drop")
    v_store = v_store.at[sa, rank].set(values[order], mode="drop")
    pos_store = pos_store.at[sa, rank].set(positions[order].astype(jnp.int32), mode="drop")
    stored = jnp.minimum(size, cap)
    return ClusterResult(k_store, v_store, pos_store, centroid, vsum, size, stored, max_pos)


def cluster_segment(keys, values, positions, avg_cluster: int, cap: int,
                    iters: int, centering: bool, valid=None) -> ClusterResult:
    """Cluster one segment: (n, hd) keys/values -> k = n // avg_cluster clusters.

    ``valid``: optional (n,) bool padding mask (see build_cluster_stores)."""
    n = keys.shape[0]
    k = max(1, n // avg_cluster)
    assign, _ = spherical_kmeans(keys, k, iters, centering, valid=valid)
    return build_cluster_stores(keys, values, positions, assign, k, cap,
                                valid=valid)


def segmented_cluster(keys, values, positions, segment: int, avg_cluster: int,
                      cap: int, iters: int, centering: bool,
                      serial: bool = False, valid=None) -> ClusterResult:
    """Cluster a (n, hd) sequence segment-by-segment; n must divide by segment.

    Returns a ClusterResult whose leading dim is total clusters n//avg_cluster,
    ordered segment-major (cluster ids are globally unique).

    ``serial=True`` runs segments through ``lax.map`` instead of ``vmap`` —
    identical results, but the k-means working set (similarity matrices,
    one-hots) is materialized for ONE segment at a time instead of all
    segments at once (§Perf: prefill peak-memory iteration).

    ``valid``: optional (n,) bool padding mask, segmented alongside the keys.
    """
    n, hd = keys.shape
    assert n % segment == 0, (n, segment)
    n_seg = n // segment
    ks = keys.reshape(n_seg, segment, hd)
    vs = values.reshape(n_seg, segment, hd)
    ps = positions.reshape(n_seg, segment)
    fn = partial(cluster_segment, avg_cluster=avg_cluster, cap=cap,
                 iters=iters, centering=centering)
    if valid is None:
        if serial:
            res = jax.lax.map(lambda args: fn(*args), (ks, vs, ps))
        else:
            res = jax.vmap(fn)(ks, vs, ps)                # (n_seg, k_per_seg, ...)
    else:
        ws = valid.reshape(n_seg, segment)
        if serial:
            res = jax.lax.map(lambda args: fn(*args[:3], valid=args[3]),
                              (ks, vs, ps, ws))
        else:
            res = jax.vmap(lambda a, b, c, d: fn(a, b, c, valid=d))(
                ks, vs, ps, ws)
    flat = jax.tree.map(lambda a: a.reshape((-1,) + a.shape[2:]), res)
    return ClusterResult(*flat)


def clustering_recall(q, keys, result: ClusterResult, r: int, topk: int = 100):
    """Recall@topk of the retrieval zone vs exact top attention scores.

    q: (hd,), keys: (n, hd). Metric used for the paper's Fig. 19b analysis.
    """
    scores = keys.astype(jnp.float32) @ q.astype(jnp.float32)
    true_top = jax.lax.top_k(scores, topk)[1]
    csc = result.centroid @ q.astype(jnp.float32)
    top_c = jax.lax.top_k(csc, r)[1]
    sel = jnp.zeros(scores.shape[0], dtype=bool)
    pos = result.pos_store[top_c].reshape(-1)             # retrieved positions
    pos0 = positions_to_local(pos, scores.shape[0])
    sel = sel.at[pos0].set(True, mode="drop")
    return jnp.mean(sel[true_top].astype(jnp.float32))


def positions_to_local(pos, n):
    """Map absolute positions to [0, n) assuming the segmenting started at 0."""
    return jnp.where(pos >= 0, pos, n)                    # -1 pads -> dropped
