"""Wave index: attention-aware cluster index over the KV cache (paper Sec. 4.2).

Per attention layer the index state holds, for every (batch, kv_head):

* fixed-capacity cluster stores (keys/values/positions) in "CPU memory" —
  on TPU: sharded HBM (see DESIGN §2),
* the meta index (centroid, value-sum, size) — small, fast-memory resident,
* the steady zone: attention sinks + a local-window ring buffer that doubles
  as the staging area for decode-time segmented clustering (flushed into new
  clusters every ``update_segment`` generated tokens).

All shapes are static. Sequence bookkeeping (``length``, ``local_len``,
``n_clusters``) is PER ROW — (B,) arrays — so a single state can hold ragged
requests at different positions, admitted and flushed independently
(continuous batching). Batch-uniform callers simply see every row agree.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import RetroConfig
from repro.core.clustering import (ClusterResult, cluster_segment,
                                   segmented_cluster)


class WaveState(NamedTuple):
    """Per-layer wave-index state. Leading dims: (B, Hkv, ...)."""
    k_store: jax.Array      # (B, H, M, cap, hd)
    v_store: jax.Array      # (B, H, M, cap, hd)
    pos_store: jax.Array    # (B, H, M, cap) int32, -1 = pad
    centroid: jax.Array     # (B, H, M, hd) f32 — meta index
    vsum: jax.Array         # (B, H, M, hd) f32 — meta index
    size: jax.Array         # (B, H, M) int32  — meta index
    stored: jax.Array       # (B, H, M) int32
    max_pos: jax.Array      # (B, H, M) int32
    n_clusters: jax.Array   # (B,) int32 — active clusters per row
    sink_k: jax.Array       # (B, H, sink, hd)
    sink_v: jax.Array       # (B, H, sink, hd)
    local_k: jax.Array      # (B, H, Lbuf, hd) ring/staging buffer
    local_v: jax.Array      # (B, H, Lbuf, hd)
    local_len: jax.Array    # (B,) int32 — valid tail of the local buffer
    length: jax.Array       # (B,) int32 — total tokens seen per row


def local_buffer_size(retro: RetroConfig) -> int:
    return retro.local + retro.update_segment


def prefill_layout(seq_len: int, retro: RetroConfig) -> Tuple[int, int, int]:
    """(n_full_segments, tail_len, n_prefill_clusters) for a prompt of seq_len.

    Clustered region = [sink, seq_len - local); full segments of
    ``prefill_segment`` plus one partial tail segment. Prompts shorter than
    sink + local have an empty clustered region (steady-zone-only plan) —
    the region is clamped to >= 0 so counts never go negative.
    """
    region = max(0, seq_len - retro.sink - retro.local)
    n_full = region // retro.prefill_segment
    tail = region - n_full * retro.prefill_segment
    m = n_full * (retro.prefill_segment // retro.avg_cluster)
    if tail > 0:
        m += max(1, tail // retro.avg_cluster)
    return n_full, tail, m


def max_clusters(seq_len: int, retro: RetroConfig, gen_headroom: int = 4096,
                 pad_multiple: int = 256) -> int:
    """Static cluster-store size: prefill clusters + decode-flush headroom,
    rounded up so the cluster axis divides the production 'model' mesh axis
    (padded clusters sit beyond ``n_clusters`` and are masked everywhere)."""
    _, _, m = prefill_layout(seq_len, retro)
    m = m + (gen_headroom // retro.update_segment) * (
        retro.update_segment // retro.avg_cluster)
    return max(pad_multiple, ((m + pad_multiple - 1) // pad_multiple) * pad_multiple)


def init_wave_state(B: int, H: int, hd: int, M: int, retro: RetroConfig,
                    dtype=jnp.bfloat16) -> WaveState:
    cap, sink, lbuf = retro.cluster_cap, retro.sink, local_buffer_size(retro)
    z = jnp.zeros
    return WaveState(
        k_store=z((B, H, M, cap, hd), dtype), v_store=z((B, H, M, cap, hd), dtype),
        pos_store=jnp.full((B, H, M, cap), -1, jnp.int32),
        centroid=z((B, H, M, hd), jnp.float32), vsum=z((B, H, M, hd), jnp.float32),
        size=z((B, H, M), jnp.int32), stored=z((B, H, M), jnp.int32),
        max_pos=jnp.full((B, H, M), -1, jnp.int32),
        n_clusters=jnp.zeros((B,), jnp.int32),
        sink_k=z((B, H, sink, hd), dtype), sink_v=z((B, H, sink, hd), dtype),
        local_k=z((B, H, lbuf, hd), dtype), local_v=z((B, H, lbuf, hd), dtype),
        local_len=jnp.zeros((B,), jnp.int32), length=jnp.zeros((B,), jnp.int32),
    )


def _write_clusters(state: WaveState, res: ClusterResult, offset) -> WaveState:
    """Write a block of freshly clustered segments at cluster ``offset``.

    res leaves have leading (B, H, k_new, ...); offset is per-row (B,) (a
    scalar broadcasts) and may be traced — rows at different fill levels
    receive their new clusters at different slots.

    ``None`` payload stores (the host-offload live view — k/v/pos live
    host-side) pass through untouched: only the meta index is written.
    """
    B = state.size.shape[0]
    off = jnp.broadcast_to(jnp.asarray(offset, jnp.int32), (B,))

    def upd(store, new):
        if store is None:
            return None
        def row(sb, nb, ob):
            start = (0, ob) + (0,) * (nb.ndim - 2)
            return jax.lax.dynamic_update_slice(sb, nb.astype(sb.dtype), start)
        return jax.vmap(row)(store, new, off)

    return state._replace(
        k_store=upd(state.k_store, res.k_store),
        v_store=upd(state.v_store, res.v_store),
        pos_store=upd(state.pos_store, res.pos_store),
        centroid=upd(state.centroid, res.centroid),
        vsum=upd(state.vsum, res.vsum),
        size=upd(state.size, res.size),
        stored=upd(state.stored, res.stored),
        max_pos=upd(state.max_pos, res.max_pos),
        n_clusters=state.n_clusters + res.size.shape[2],
    )


def prefill_build(k: jax.Array, v: jax.Array, retro: RetroConfig, M: int,
                  dtype=None, lengths: Optional[jax.Array] = None) -> WaveState:
    """Build the wave index from prefill K/V.

    k, v: (B, S, H, hd) post-RoPE. Returns a WaveState with the prompt's
    sink/local/steady zones populated and all segments clustered.

    ``lengths``: optional (B,) int32 true prompt lengths for right-padded
    ragged batches (each row's real tokens occupy [0, lengths[b])). Each row's
    local window is its last ``local`` REAL tokens and only tokens in
    [sink, lengths[b] - local) enter clusters — padding never reaches a store,
    so it can never leak into attention as generation extends past it.
    Requires lengths[b] >= sink + local. None = every row uses all S tokens.
    """
    B, S, H, hd = k.shape
    dtype = dtype or k.dtype
    retro_sink = retro.sink
    # S <= sink would under-fill the fixed-width sink zone, whose positions
    # are implicit (arange(sink)): the empty slots' zero keys would become
    # attendable once generation pushes length past them.
    if S <= retro_sink:
        raise ValueError(
            f"prompt length {S} must exceed the sink width {retro_sink}")
    local = min(retro.local, max(S - retro_sink, 0))
    n_full, tail, _ = prefill_layout(S, retro)
    state = init_wave_state(B, H, hd, M, retro, dtype)

    kbh = jnp.swapaxes(k, 1, 2)                            # (B, H, S, hd)
    vbh = jnp.swapaxes(v, 1, 2)

    if lengths is None:
        lens = jnp.full((B,), S, jnp.int32)
        valid = None
    else:
        lens = jnp.asarray(lengths, jnp.int32)
        # cluster-valid tokens: [sink, lens - local) per row
        valid = jnp.arange(S)[None, :] < (lens - local)[:, None]

    # per-row local window: the last ``local`` real tokens [lens-local, lens)
    win0 = jnp.maximum(lens - local, 0)

    def take_local(xb, s):
        return jax.lax.dynamic_slice(xb, (0, s, 0), (H, local, hd))

    lk = jax.vmap(take_local)(kbh, win0).astype(state.local_k.dtype)
    lv = jax.vmap(take_local)(vbh, win0).astype(state.local_v.dtype)

    state = state._replace(
        sink_k=kbh[:, :, :retro_sink].astype(state.sink_k.dtype),
        sink_v=vbh[:, :, :retro_sink].astype(state.sink_v.dtype),
        local_k=jax.lax.dynamic_update_slice(state.local_k, lk, (0, 0, 0, 0)),
        local_v=jax.lax.dynamic_update_slice(state.local_v, lv, (0, 0, 0, 0)),
        local_len=jnp.full((B,), local, jnp.int32),
        length=lens,
    )

    pos = jnp.arange(S, dtype=jnp.int32)
    seg = retro.prefill_segment

    if n_full > 0:
        s0, span = retro_sink, n_full * seg

        def row_full(kk, vv, vm):
            def bh(k1, v1):
                return segmented_cluster(
                    k1[s0:s0 + span], v1[s0:s0 + span], pos[s0:s0 + span],
                    seg, retro.avg_cluster, retro.cluster_cap,
                    retro.kmeans_iters, retro.centering,
                    serial=retro.serial_prefill_segments, valid=vm)
            return jax.vmap(bh)(kk, vv)

        if valid is None:
            res = jax.vmap(partial(row_full, vm=None))(kbh, vbh)
        else:
            res = jax.vmap(row_full)(kbh, vbh, valid[:, s0:s0 + span])
        state = _write_clusters(state, res, 0)

    if tail > 0:
        t0 = retro_sink + n_full * seg

        def row_tail(kk, vv, vm):
            def bh(k1, v1):
                return cluster_segment(k1[t0:t0 + tail], v1[t0:t0 + tail],
                                       pos[t0:t0 + tail], retro.avg_cluster,
                                       retro.cluster_cap, retro.kmeans_iters,
                                       retro.centering, valid=vm)
            return jax.vmap(bh)(kk, vv)

        if valid is None:
            res_t = jax.vmap(partial(row_tail, vm=None))(kbh, vbh)
        else:
            res_t = jax.vmap(row_tail)(kbh, vbh, valid[:, t0:t0 + tail])
        state = _write_clusters(state, res_t, state.n_clusters)

    return state


# ---------------------------------------------------------------------------
# Chunked (streaming) prefill build — admission interleaved with decode.
#
# ``prefill_build`` consumes the whole prompt at once; a serving engine that
# wants to admit a request WITHOUT stalling in-flight decodes instead streams
# the prompt through ``prefill_append_chunk`` a fixed-size chunk at a time and
# closes the build with ``prefill_finalize``. The final WaveState is
# bit-identical to ``prefill_build`` on the full prompt for ANY chunk split:
# segment boundaries are position- (not chunk-) aligned, and a full segment is
# only clustered once ``local`` further tokens have arrived — those tokens can
# no longer end up in the final local window, so greedy flushing reproduces
# exactly the segments the monolithic layout would cluster.
# ---------------------------------------------------------------------------


class ChunkedPrefill(NamedTuple):
    """Streaming prefill-build state.

    ``state`` is the WaveState under construction: the sink zone and cluster
    stores fill as chunks arrive; the local window and length bookkeeping are
    written by ``prefill_finalize``. ``stage_*`` hold the not-yet-clustered
    tokens past the sink — row b's staged tokens sit at absolute positions
    [seen[b] - staged[b], seen[b]).
    """
    state: WaveState
    stage_k: jax.Array      # (B, H, stage_cap, hd)
    stage_v: jax.Array
    staged: jax.Array       # (B,) int32 — valid tokens in the staging buffer
    seen: jax.Array         # (B,) int32 — prompt tokens consumed so far


def stage_capacity(retro: RetroConfig, chunk: int) -> int:
    """Staging-buffer size for chunked prefill: between flushes the buffer
    holds < prefill_segment + local tokens, plus one incoming chunk."""
    return retro.prefill_segment + retro.local + chunk


def init_chunked_prefill(B: int, H: int, hd: int, M: int, retro: RetroConfig,
                         chunk: int, dtype=jnp.bfloat16,
                         stage_dtype=None) -> ChunkedPrefill:
    """Fresh streaming build for prompts fed in chunks of <= ``chunk`` tokens.

    ``stage_dtype`` should match the dtype of the incoming K/V chunks (default:
    ``dtype``) — clustering reads the staged copies, and bit-identity with
    ``prefill_build`` (which clusters the raw input) needs them unconverted.
    """
    cap = stage_capacity(retro, chunk)
    sd = dtype if stage_dtype is None else stage_dtype
    return ChunkedPrefill(
        state=init_wave_state(B, H, hd, M, retro, dtype),
        stage_k=jnp.zeros((B, H, cap, hd), sd),
        stage_v=jnp.zeros((B, H, cap, hd), sd),
        staged=jnp.zeros((B,), jnp.int32),
        seen=jnp.zeros((B,), jnp.int32))


def _where_rows(rows: jax.Array, new, old):
    """Per-row select over matching pytrees (leading dim B)."""
    B = rows.shape[0]
    return jax.tree.map(
        lambda n, o: jnp.where(rows.reshape((B,) + (1,) * (n.ndim - 1)), n, o),
        new, old)


def scatter_chunk_rows(buf: jax.Array, chunk: jax.Array,
                       idx: jax.Array) -> jax.Array:
    """Per-row scatter of a token chunk into a buffer's token axis.

    buf: (B, H, N, hd); chunk: (B, H, C, hd); idx: (B, C) target token slots —
    out-of-range entries (>= N) are DROPPED, so callers route/pad by clamping
    unwanted writes past the end instead of masking."""
    return jax.vmap(
        lambda b, c, i: b.at[:, i].set(c.astype(b.dtype), mode="drop")
    )(buf, chunk, idx)


def _flush_stage(cp: ChunkedPrefill, retro: RetroConfig) -> ChunkedPrefill:
    """Cluster the oldest full prefill segment of each SAFE staging buffer.

    A row is flushed when its staging buffer holds prefill_segment + local
    tokens: the oldest segment then provably ends >= ``local`` before the
    final prompt end, so it is one of the full segments ``prefill_build``
    would cluster. Rows below the threshold pass through bit-unchanged.
    """
    seg = retro.prefill_segment
    rows = cp.staged >= seg + retro.local
    start = cp.seen - cp.staged                  # abs position of stage[0]
    pos = start[:, None] + jnp.arange(seg, dtype=jnp.int32)[None, :]

    def row_fn(kk, vv, p):
        def bh(k1, v1):
            return cluster_segment(k1[:seg], v1[:seg], p, retro.avg_cluster,
                                   retro.cluster_cap, retro.kmeans_iters,
                                   retro.centering)
        return jax.vmap(bh)(kk, vv)

    res = jax.vmap(row_fn)(cp.stage_k, cp.stage_v, pos)
    flushed = _write_clusters(cp.state, res, cp.state.n_clusters)
    return ChunkedPrefill(
        state=_where_rows(rows, flushed, cp.state),
        stage_k=_where_rows(rows, jnp.roll(cp.stage_k, -seg, axis=2),
                            cp.stage_k),
        stage_v=_where_rows(rows, jnp.roll(cp.stage_v, -seg, axis=2),
                            cp.stage_v),
        staged=jnp.where(rows, cp.staged - seg, cp.staged),
        seen=cp.seen)


def prefill_append_chunk(cp: ChunkedPrefill, k_chunk: jax.Array,
                         v_chunk: jax.Array, retro: RetroConfig,
                         chunk_lens: Optional[jax.Array] = None
                         ) -> ChunkedPrefill:
    """Extend a streaming build with the next (B, C, H, hd) chunk of prompt K/V.

    Tokens are routed by absolute position: positions < sink fill the sink
    zone, the rest append to the staging buffer; whenever a row has staged a
    full ``prefill_segment`` plus the ``local`` safety margin, the oldest
    segment is clustered (per-row masked) exactly as ``prefill_build`` would.

    ``chunk_lens``: optional (B,) int32 valid prefix of this chunk per row
    (right-padded final chunks; rows may advance at different rates — a row
    with 0 consumes nothing and is bit-unchanged).
    """
    B, C, H, hd = k_chunk.shape
    sink = retro.sink
    clens = jnp.full((B,), C, jnp.int32) if chunk_lens is None \
        else jnp.asarray(chunk_lens, jnp.int32)
    kc = jnp.swapaxes(k_chunk, 1, 2)                        # (B, H, C, hd)
    vc = jnp.swapaxes(v_chunk, 1, 2)

    j = jnp.arange(C, dtype=jnp.int32)[None, :]             # (1, C)
    p = cp.seen[:, None] + j                                # (B, C) abs pos
    valid = j < clens[:, None]

    # scatter with out-of-range index => dropped write (per-row routing)
    sink_idx = jnp.where(valid & (p < sink), p, sink)
    j0 = jnp.clip(sink - cp.seen, 0, C)                     # first staged j
    stage_cap = cp.stage_k.shape[2]
    stage_idx = jnp.where(valid & (p >= sink),
                          cp.staged[:, None] + j - j0[:, None], stage_cap)

    scat = scatter_chunk_rows
    state = cp.state._replace(sink_k=scat(cp.state.sink_k, kc, sink_idx),
                              sink_v=scat(cp.state.sink_v, vc, sink_idx))
    cp = ChunkedPrefill(
        state=state,
        stage_k=scat(cp.stage_k, kc, stage_idx),
        stage_v=scat(cp.stage_v, vc, stage_idx),
        staged=cp.staged + (clens - jnp.clip(sink - cp.seen, 0, clens)),
        seen=cp.seen + clens)
    # a C-token chunk can complete at most ceil(C / segment) segments
    for _ in range(-(-C // retro.prefill_segment)):
        cp = _flush_stage(cp, retro)
    return cp


def prefill_finalize(cp: ChunkedPrefill, retro: RetroConfig,
                     total_len: int) -> WaveState:
    """Close a streaming build: cluster the partial tail segment and install
    the local window. ``total_len`` is static and must equal every row's
    consumed token count (``cp.seen``); rows that streamed at different rates
    must have converged. The result is bit-identical to ``prefill_build`` on
    the same prompt."""
    if total_len <= retro.sink:
        raise ValueError(
            f"prompt length {total_len} must exceed the sink width {retro.sink}")
    local = min(retro.local, total_len - retro.sink)
    _, tail, _ = prefill_layout(total_len, retro)
    state = cp.state
    B, H, _, hd = state.local_k.shape

    if tail > 0:
        start = cp.seen - cp.staged
        pos = start[:, None] + jnp.arange(tail, dtype=jnp.int32)[None, :]

        def row_fn(kk, vv, p):
            def bh(k1, v1):
                return cluster_segment(k1[:tail], v1[:tail], p,
                                       retro.avg_cluster, retro.cluster_cap,
                                       retro.kmeans_iters, retro.centering)
            return jax.vmap(bh)(kk, vv)

        res = jax.vmap(row_fn)(cp.stage_k, cp.stage_v, pos)
        state = _write_clusters(state, res, state.n_clusters)

    lk = cp.stage_k[:, :, tail:tail + local].astype(state.local_k.dtype)
    lv = cp.stage_v[:, :, tail:tail + local].astype(state.local_v.dtype)
    return state._replace(
        local_k=jax.lax.dynamic_update_slice(state.local_k, lk, (0, 0, 0, 0)),
        local_v=jax.lax.dynamic_update_slice(state.local_v, lv, (0, 0, 0, 0)),
        local_len=jnp.full((B,), local, jnp.int32),
        length=cp.seen)


def append_token(state: WaveState, k_new: jax.Array, v_new: jax.Array,
                 active: Optional[jax.Array] = None) -> WaveState:
    """Append one generated token's (B, H, hd) K/V to the local buffer.

    Rows write at their own ``local_len`` cursor. ``active``: optional (B,)
    bool — inactive rows (free slots in a continuous batch) are left
    untouched so their counters never drift or overflow the staging buffer.
    """
    k_new = k_new[:, :, None, :].astype(state.local_k.dtype)
    v_new = v_new[:, :, None, :].astype(state.local_v.dtype)

    def row(buf, new, idx):
        return jax.lax.dynamic_update_slice(buf, new, (0, idx, 0))

    new_lk = jax.vmap(row)(state.local_k, k_new, state.local_len)
    new_lv = jax.vmap(row)(state.local_v, v_new, state.local_len)
    step = jnp.ones_like(state.local_len)
    if active is not None:
        act = jnp.asarray(active)
        sel = act[:, None, None, None]
        new_lk = jnp.where(sel, new_lk, state.local_k)
        new_lv = jnp.where(sel, new_lv, state.local_v)
        step = act.astype(state.local_len.dtype)
    return state._replace(
        local_k=new_lk, local_v=new_lv,
        local_len=state.local_len + step,
        length=state.length + step,
    )


def flush_segment(state: WaveState, retro: RetroConfig,
                  rows: Optional[jax.Array] = None,
                  return_clusters: bool = False):
    """Cluster the oldest ``update_segment`` tokens of each FULL local buffer
    into new clusters (paper: decode-time index update, every 1K tokens) and
    slide the remaining ``local`` tokens to the front.

    Per-row masked: under continuous batching rows fill their staging buffers
    at different steps, so only rows selected by ``rows`` (default: buffer
    full) are flushed; the rest pass through bit-unchanged.

    ``return_clusters=True`` additionally returns the freshly clustered
    ``ClusterResult`` (all rows — callers apply their own ``rows`` mask);
    with ``None`` payload stores (host-offload live view) only the meta index
    is written on device and the returned blocks are the host store's append.
    """
    useg = retro.update_segment
    lbuf = local_buffer_size(retro)
    B, H, _, hd = state.local_k.shape
    if rows is None:
        rows = state.local_len >= lbuf
    rows = jnp.asarray(rows)
    start = state.length - state.local_len                 # abs pos of buffer[0]
    pos = start[:, None] + jnp.arange(useg, dtype=jnp.int32)[None, :]

    def row_fn(kk, vv, p):
        def bh(k1, v1):
            return cluster_segment(k1[:useg], v1[:useg], p, retro.avg_cluster,
                                   retro.cluster_cap, retro.kmeans_iters,
                                   retro.centering)
        return jax.vmap(bh)(kk, vv)

    res = jax.vmap(row_fn)(state.local_k, state.local_v, pos)
    flushed = _write_clusters(state, res, state.n_clusters)

    rolled_k = jnp.roll(state.local_k, -useg, axis=2)
    rolled_v = jnp.roll(state.local_v, -useg, axis=2)

    def sel(new, old):
        if new is None:                    # host-resident payload store
            return None
        return jnp.where(rows.reshape((B,) + (1,) * (new.ndim - 1)), new, old)

    out = state._replace(
        k_store=sel(flushed.k_store, state.k_store),
        v_store=sel(flushed.v_store, state.v_store),
        pos_store=sel(flushed.pos_store, state.pos_store),
        centroid=sel(flushed.centroid, state.centroid),
        vsum=sel(flushed.vsum, state.vsum),
        size=sel(flushed.size, state.size),
        stored=sel(flushed.stored, state.stored),
        max_pos=sel(flushed.max_pos, state.max_pos),
        n_clusters=jnp.where(rows, flushed.n_clusters, state.n_clusters),
        local_k=sel(rolled_k, state.local_k),
        local_v=sel(rolled_v, state.local_v),
        local_len=jnp.where(rows, state.local_len - useg, state.local_len),
    )
    return (out, res) if return_clusters else out


def flush_segment_offload(state: WaveState, retro: RetroConfig,
                          rows: Optional[jax.Array] = None
                          ) -> Tuple[WaveState, ClusterResult]:
    """``flush_segment`` for the host-offload configuration: identical
    clustering and meta-index update, with the PAYLOAD blocks returned for
    the host control plane to append to its resident store (at each flushed
    row's old ``n_clusters`` offset). ``state`` carries ``None`` payload
    stores (the serve engine's live view); they pass through untouched."""
    return flush_segment(state, retro, rows=rows, return_clusters=True)


def maybe_flush(state: WaveState, retro: RetroConfig) -> WaveState:
    """Flush inside jit iff any row's staging buffer is full (per-row masked)."""
    full = state.local_len >= local_buffer_size(retro)
    return jax.lax.cond(jnp.any(full),
                        lambda s: flush_segment(s, retro), lambda s: s, state)
