"""Wave index: attention-aware cluster index over the KV cache (paper Sec. 4.2).

Per attention layer the index state holds, for every (batch, kv_head):

* fixed-capacity cluster stores (keys/values/positions) in "CPU memory" —
  on TPU: sharded HBM (see DESIGN §2),
* the meta index (centroid, value-sum, size) — small, fast-memory resident,
* the steady zone: attention sinks + a local-window ring buffer that doubles
  as the staging area for decode-time segmented clustering (flushed into new
  clusters every ``update_segment`` generated tokens).

All shapes are static; the active cluster count is a traced scalar.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import RetroConfig
from repro.core.clustering import (ClusterResult, cluster_segment,
                                   segmented_cluster)


class WaveState(NamedTuple):
    """Per-layer wave-index state. Leading dims: (B, Hkv, ...)."""
    k_store: jax.Array      # (B, H, M, cap, hd)
    v_store: jax.Array      # (B, H, M, cap, hd)
    pos_store: jax.Array    # (B, H, M, cap) int32, -1 = pad
    centroid: jax.Array     # (B, H, M, hd) f32 — meta index
    vsum: jax.Array         # (B, H, M, hd) f32 — meta index
    size: jax.Array         # (B, H, M) int32  — meta index
    stored: jax.Array       # (B, H, M) int32
    max_pos: jax.Array      # (B, H, M) int32
    n_clusters: jax.Array   # () int32 — active clusters
    sink_k: jax.Array       # (B, H, sink, hd)
    sink_v: jax.Array       # (B, H, sink, hd)
    local_k: jax.Array      # (B, H, Lbuf, hd) ring/staging buffer
    local_v: jax.Array      # (B, H, Lbuf, hd)
    local_len: jax.Array    # () int32 — valid tail of the local buffer
    length: jax.Array       # () int32 — total tokens seen


def local_buffer_size(retro: RetroConfig) -> int:
    return retro.local + retro.update_segment


def prefill_layout(seq_len: int, retro: RetroConfig) -> Tuple[int, int, int]:
    """(n_full_segments, tail_len, n_prefill_clusters) for a prompt of seq_len.

    Clustered region = [sink, seq_len - local); full segments of
    ``prefill_segment`` plus one partial tail segment.
    """
    region = seq_len - retro.sink - retro.local
    n_full = region // retro.prefill_segment
    tail = region - n_full * retro.prefill_segment
    m = n_full * (retro.prefill_segment // retro.avg_cluster)
    if tail > 0:
        m += max(1, tail // retro.avg_cluster)
    return n_full, tail, m


def max_clusters(seq_len: int, retro: RetroConfig, gen_headroom: int = 4096,
                 pad_multiple: int = 256) -> int:
    """Static cluster-store size: prefill clusters + decode-flush headroom,
    rounded up so the cluster axis divides the production 'model' mesh axis
    (padded clusters sit beyond ``n_clusters`` and are masked everywhere)."""
    _, _, m = prefill_layout(seq_len, retro)
    m = m + (gen_headroom // retro.update_segment) * (
        retro.update_segment // retro.avg_cluster)
    return ((m + pad_multiple - 1) // pad_multiple) * pad_multiple


def init_wave_state(B: int, H: int, hd: int, M: int, retro: RetroConfig,
                    dtype=jnp.bfloat16) -> WaveState:
    cap, sink, lbuf = retro.cluster_cap, retro.sink, local_buffer_size(retro)
    z = jnp.zeros
    return WaveState(
        k_store=z((B, H, M, cap, hd), dtype), v_store=z((B, H, M, cap, hd), dtype),
        pos_store=jnp.full((B, H, M, cap), -1, jnp.int32),
        centroid=z((B, H, M, hd), jnp.float32), vsum=z((B, H, M, hd), jnp.float32),
        size=z((B, H, M), jnp.int32), stored=z((B, H, M), jnp.int32),
        max_pos=jnp.full((B, H, M), -1, jnp.int32),
        n_clusters=jnp.zeros((), jnp.int32),
        sink_k=z((B, H, sink, hd), dtype), sink_v=z((B, H, sink, hd), dtype),
        local_k=z((B, H, lbuf, hd), dtype), local_v=z((B, H, lbuf, hd), dtype),
        local_len=jnp.zeros((), jnp.int32), length=jnp.zeros((), jnp.int32),
    )


def _write_clusters(state: WaveState, res: ClusterResult, offset) -> WaveState:
    """Write a block of freshly clustered segments at cluster ``offset``.

    res leaves have leading (B, H, k_new, ...); offset may be traced.
    """
    def upd(store, new):
        start = (0, 0, offset) + (0,) * (new.ndim - 3)
        return jax.lax.dynamic_update_slice(store, new.astype(store.dtype), start)

    return state._replace(
        k_store=upd(state.k_store, res.k_store),
        v_store=upd(state.v_store, res.v_store),
        pos_store=upd(state.pos_store, res.pos_store),
        centroid=upd(state.centroid, res.centroid),
        vsum=upd(state.vsum, res.vsum),
        size=upd(state.size, res.size),
        stored=upd(state.stored, res.stored),
        max_pos=upd(state.max_pos, res.max_pos),
        n_clusters=state.n_clusters + res.size.shape[2],
    )


def prefill_build(k: jax.Array, v: jax.Array, retro: RetroConfig, M: int,
                  dtype=None) -> WaveState:
    """Build the wave index from prefill K/V.

    k, v: (B, S, H, hd) post-RoPE. Returns a WaveState with the prompt's
    sink/local/steady zones populated and all segments clustered.
    """
    B, S, H, hd = k.shape
    dtype = dtype or k.dtype
    retro_sink, local = retro.sink, retro.local
    n_full, tail, _ = prefill_layout(S, retro)
    state = init_wave_state(B, H, hd, M, retro, dtype)

    kbh = jnp.swapaxes(k, 1, 2)                            # (B, H, S, hd)
    vbh = jnp.swapaxes(v, 1, 2)
    state = state._replace(
        sink_k=kbh[:, :, :retro_sink], sink_v=vbh[:, :, :retro_sink],
        local_k=jax.lax.dynamic_update_slice(
            state.local_k, kbh[:, :, S - local:], (0, 0, 0, 0)),
        local_v=jax.lax.dynamic_update_slice(
            state.local_v, vbh[:, :, S - local:], (0, 0, 0, 0)),
        local_len=jnp.asarray(local, jnp.int32),
        length=jnp.asarray(S, jnp.int32),
    )

    pos = jnp.arange(S, dtype=jnp.int32)
    seg = retro.prefill_segment

    def bh_full(kk, vv):
        s0 = retro_sink
        return segmented_cluster(kk[s0:s0 + n_full * seg], vv[s0:s0 + n_full * seg],
                                 pos[s0:s0 + n_full * seg], seg, retro.avg_cluster,
                                 retro.cluster_cap, retro.kmeans_iters, retro.centering,
                                 serial=retro.serial_prefill_segments)

    if n_full > 0:
        res = jax.vmap(jax.vmap(bh_full))(kbh, vbh)
        state = _write_clusters(state, res, 0)

    if tail > 0:
        t0 = retro_sink + n_full * seg

        def bh_tail(kk, vv):
            return cluster_segment(kk[t0:t0 + tail], vv[t0:t0 + tail],
                                   pos[t0:t0 + tail], retro.avg_cluster,
                                   retro.cluster_cap, retro.kmeans_iters,
                                   retro.centering)

        res_t = jax.vmap(jax.vmap(bh_tail))(kbh, vbh)
        state = _write_clusters(state, res_t, state.n_clusters)

    return state


def append_token(state: WaveState, k_new: jax.Array, v_new: jax.Array) -> WaveState:
    """Append one generated token's (B, H, hd) K/V to the local buffer."""
    idx = state.local_len
    k_new = k_new[:, :, None, :].astype(state.local_k.dtype)
    v_new = v_new[:, :, None, :].astype(state.local_v.dtype)
    return state._replace(
        local_k=jax.lax.dynamic_update_slice(state.local_k, k_new, (0, 0, idx, 0)),
        local_v=jax.lax.dynamic_update_slice(state.local_v, v_new, (0, 0, idx, 0)),
        local_len=state.local_len + 1,
        length=state.length + 1,
    )


def flush_segment(state: WaveState, retro: RetroConfig) -> WaveState:
    """Cluster the oldest ``update_segment`` tokens of a full local buffer into
    new clusters (paper: decode-time index update, every 1K tokens) and slide
    the remaining ``local`` tokens to the front.
    """
    useg, local = retro.update_segment, retro.local
    lbuf = local_buffer_size(retro)
    B, H, _, hd = state.local_k.shape
    start = state.length - state.local_len                 # abs pos of buffer[0]
    pos = (start + jnp.arange(useg, dtype=jnp.int32))

    def bh(kk, vv):
        return cluster_segment(kk[:useg], vv[:useg], pos, retro.avg_cluster,
                               retro.cluster_cap, retro.kmeans_iters, retro.centering)

    res = jax.vmap(jax.vmap(bh))(state.local_k, state.local_v)
    state = _write_clusters(state, res, state.n_clusters)

    # slide the local window to the front of the staging buffer
    rolled_k = jnp.roll(state.local_k, -useg, axis=2)
    rolled_v = jnp.roll(state.local_v, -useg, axis=2)
    return state._replace(local_k=rolled_k, local_v=rolled_v,
                          local_len=state.local_len - useg)


def maybe_flush(state: WaveState, retro: RetroConfig) -> WaveState:
    """Flush inside jit iff the staging buffer is full."""
    full = state.local_len >= local_buffer_size(retro)
    return jax.lax.cond(full, lambda s: flush_segment(s, retro), lambda s: s, state)
