"""Synthetic data pipeline (no external datasets in this container).

Two generators:

* ``lm_batches`` — Zipf-distributed token streams with local Markov structure
  (so losses are learnable, not pure noise) for the training substrate.
* ``needle_prompt`` — RULER/NIAH-style structured prompts: a long "haystack"
  with key-value "needles" planted at controlled depths. Used by the accuracy
  benchmarks to reproduce the paper's retrieval-quality experiments, since the
  retrieval difficulty (scattered important tokens) matches Fig. 3.

Deterministic given seed. Batches are dicts matching ``registry.input_specs``.
"""
from __future__ import annotations

from typing import Dict, Iterator, List, Optional, Tuple

import numpy as np

from repro.configs.base import ModelConfig


def _zipf_markov(rng: np.random.Generator, n: int, vocab: int,
                 alpha: float = 1.2, repeat_p: float = 0.3) -> np.ndarray:
    """Zipfian unigram with a copy-previous channel => learnable structure."""
    ranks = np.arange(1, vocab + 1)
    probs = ranks ** (-alpha)
    probs /= probs.sum()
    base = rng.choice(vocab, size=n, p=probs)
    copy = rng.random(n) < repeat_p
    out = base.copy()
    for i in range(1, n):
        if copy[i]:
            out[i] = out[i - 1]
    return out.astype(np.int32)


def lm_batches(cfg: ModelConfig, batch: int, seq: int, seed: int = 0,
               frontend_dim: Optional[int] = None) -> Iterator[Dict]:
    """Infinite iterator of {tokens, targets, [patch_embeds|frames]}."""
    rng = np.random.default_rng(seed)
    while True:
        toks = np.stack([_zipf_markov(rng, seq + 1, cfg.vocab)
                         for _ in range(batch)])
        out = {"tokens": toks[:, :-1], "targets": toks[:, 1:]}
        if cfg.family == "vlm":
            out["patch_embeds"] = rng.standard_normal(
                (batch, cfg.num_patch_tokens, cfg.d_model)).astype(np.float32)
        if cfg.family == "audio":
            out["frames"] = rng.standard_normal(
                (batch, cfg.encoder_frames, cfg.d_model)).astype(np.float32)
        yield out


def shard_batch(batch: Dict, n_hosts: int, host_id: int) -> Dict:
    """Static per-host slicing of the global batch (data-parallel input)."""
    def sl(a):
        per = a.shape[0] // n_hosts
        return a[host_id * per:(host_id + 1) * per]
    return {k: sl(v) for k, v in batch.items()}


# ---------------------------------------------------------------------------
# structured retrieval workloads (accuracy benchmarks)
# ---------------------------------------------------------------------------

def needle_prompt(vocab: int, seq: int, n_needles: int, seed: int = 0,
                  needle_span: int = 8) -> Tuple[np.ndarray, List[int]]:
    """A haystack of filler tokens with ``n_needles`` rare-token spans planted
    at scattered depths. Returns (tokens (seq,), needle_positions)."""
    rng = np.random.default_rng(seed)
    filler_vocab = max(16, vocab // 4)
    toks = rng.integers(0, filler_vocab, size=seq)
    needle_tok = vocab - 1 - np.arange(n_needles)         # rare ids
    positions = np.sort(rng.choice(
        np.arange(seq // 10, seq - seq // 10), size=n_needles, replace=False))
    for i, p in enumerate(positions):
        toks[p:p + needle_span] = needle_tok[i]
    return toks.astype(np.int32), positions.tolist()


def clustered_keys(n: int, hd: int, n_hot: int = 4, seed: int = 0,
                   noise: float = 0.25) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Synthetic post-RoPE-like key field with planted 'important' directions.

    Returns (keys (n, hd), query (hd,), hot_mask (n,)). ``n_hot`` scattered
    stretches of keys are aligned with the query (high inner product) — the
    dynamic-sparsity structure of paper Fig. 3 — the rest is segment-locally
    correlated background (the RoPE spatial locality of Sec. 4.2).
    """
    rng = np.random.default_rng(seed)
    q = rng.standard_normal(hd)
    q /= np.linalg.norm(q)
    scale = np.sqrt(hd)                 # realistic key norms (~sqrt(d))
    seg = max(32, n // 64)
    keys = np.empty((n, hd), np.float32)
    for s in range(0, n, seg):
        center = rng.standard_normal(hd)
        center /= np.linalg.norm(center)
        e = min(n, s + seg)
        keys[s:e] = scale * (center + noise * rng.standard_normal((e - s, hd)))
    hot = np.zeros(n, bool)
    for p in rng.choice(n - 16, size=n_hot, replace=False):
        # hot spans score ~5 sigma above background after 1/sqrt(d) scaling
        keys[p:p + 16] = scale * (5.0 * q
                                  + noise * rng.standard_normal((16, hd)))
        hot[p:p + 16] = True
    return keys.astype(np.float32), q.astype(np.float32), hot


def assoc_recall_batch(rng: np.random.Generator, batch: int, n_pairs: int,
                       vocab: int, seq: Optional[int] = None,
                       query_of: Optional[int] = None):
    """Associative-recall (NIAH-style) task: ``k1 v1 k2 v2 ... kq -> vq``.

    Keys live in [2, vocab/2), values in [vocab/2, vocab). The prompt ends
    with a repeated query key; the target is its value. This is the miniature
    form of the paper's needle-retrieval evaluation — important tokens (the
    queried pair) are scattered at arbitrary depth.

    Returns (tokens (B, T), targets (B,)) with T = 2*n_pairs + 1 (padded to
    ``seq`` with filler token 1 in front if given).
    """
    lo_k, hi_k = 2, vocab // 2
    lo_v, hi_v = vocab // 2, vocab
    T = 2 * n_pairs + 1
    toks = np.ones((batch, seq or T), np.int32)
    targets = np.zeros((batch,), np.int32)
    for b in range(batch):
        keys = rng.choice(np.arange(lo_k, hi_k), size=n_pairs, replace=False)
        vals = rng.integers(lo_v, hi_v, size=n_pairs)
        qi = int(rng.integers(0, n_pairs)) if query_of is None else query_of
        body = np.empty(T, np.int32)
        body[0:2 * n_pairs:2] = keys
        body[1:2 * n_pairs:2] = vals
        body[-1] = keys[qi]
        toks[b, -T:] = body
        targets[b] = vals[qi]
    return toks, targets
